"""Predictive fault-duration estimation for the ski-rental planner.

The paper's Algorithm 1 escalates when the *accumulated* fail-slow impact
crosses the next strategy's overhead — the classic ski-rental rule, which
implicitly assumes the fault may last forever. The §3 characterization
says otherwise: episode durations are heavy-tailed but *predictable in
distribution* (log-spread from tens of seconds to ~10 hours, with strong
per-cause structure). :class:`DurationModel` turns that into a survival
curve per root cause:

* **Prior** — log-spaced pseudo-observations over the §3 duration range
  (20 s .. 10 h), so a fresh fleet already reasons about remaining
  duration instead of assuming an infinite horizon.
* **Online fit** — every resolved fail-slow feeds its observed duration
  back (:meth:`observe`); durations ended by our *own* checkpoint-restart
  are right-censored (the fault would have lasted longer), handled with a
  weighted Kaplan-Meier estimator so mitigation does not bias the curve
  downward.

:meth:`expected_remaining` is the planner's query: the conditional mean
remaining duration E[T - t | T > t] for a fault of the given cause that
has already survived ``age`` seconds — left-truncated at the age, so the
heavy tail is weighed exactly as much as the evidence supports.
"""
from __future__ import annotations

import math
from bisect import insort
from dataclasses import dataclass, field

from repro.core.events import RootCause

#: §3 duration support: tens of seconds to ~10 hours (Fig. 1 CDF).
PRIOR_RANGE_S: tuple[float, float] = (20.0, 36_000.0)


@dataclass(frozen=True)
class _Sample:
    """One (possibly censored) duration observation."""

    duration: float
    weight: float
    censored: bool  # True: fault outlived the observation (lower bound)

    def __lt__(self, other: "_Sample") -> bool:  # insort ordering
        return self.duration < other.duration


def _log_spaced_prior(
    lo: float, hi: float, points: int, total_weight: float
) -> list[_Sample]:
    w = total_weight / points
    if points == 1:
        return [_Sample(math.sqrt(lo * hi), w, False)]
    ratio = math.log(hi / lo) / (points - 1)
    return [
        _Sample(lo * math.exp(ratio * i), w, False) for i in range(points)
    ]


@dataclass
class DurationModel:
    """Per-cause survival curves, prior-seeded and fit online."""

    prior_range_s: tuple[float, float] = PRIOR_RANGE_S
    prior_points: int = 12
    #: total pseudo-observation weight of the prior (per cause); real
    #: observations carry weight 1 each, so ~this many resolutions make
    #: the data dominate
    prior_weight: float = 6.0

    _samples: dict[RootCause, list[_Sample]] = field(
        init=False, default_factory=dict
    )
    _n_observed: dict[RootCause, int] = field(init=False, default_factory=dict)

    def _cause_samples(self, cause: RootCause) -> list[_Sample]:
        if cause is RootCause.UNKNOWN:
            # Unattributed faults pool the evidence of every cause.
            out: list[_Sample] = []
            for c in RootCause:
                if c is not RootCause.UNKNOWN:
                    out += self._bucket(c)
            return sorted(out)
        return self._bucket(cause)

    def _bucket(self, cause: RootCause) -> list[_Sample]:
        if cause not in self._samples:
            lo, hi = self.prior_range_s
            self._samples[cause] = _log_spaced_prior(
                lo, hi, self.prior_points, self.prior_weight
            )
        return self._samples[cause]

    # ------------------------------------------------------------------
    def observe(
        self, cause: RootCause, duration: float, censored: bool = False
    ) -> None:
        """Record one resolved fail-slow episode's duration.

        ``censored=True`` marks durations ended by our own mitigation
        (checkpoint-restart clears the fault): the true duration is only
        bounded below, and Kaplan-Meier weighs it accordingly.
        """
        if duration <= 0:
            return
        if cause is RootCause.UNKNOWN:
            return  # nothing to attribute the duration to
        insort(self._bucket(cause), _Sample(float(duration), 1.0, censored))
        self._n_observed[cause] = self._n_observed.get(cause, 0) + 1

    def n_observed(self, cause: RootCause) -> int:
        return self._n_observed.get(cause, 0)

    # -- state capture (campaign fork/restore contract) ----------------
    def snapshot(self) -> dict:
        """All fitted state as private copies (samples are frozen
        dataclasses, so copying the lists suffices)."""
        return {
            "samples": {c: list(s) for c, s in self._samples.items()},
            "n_observed": dict(self._n_observed),
        }

    def restore(self, snap: dict) -> None:
        self._samples = {c: list(s) for c, s in snap["samples"].items()}
        self._n_observed = dict(snap["n_observed"])

    # ------------------------------------------------------------------
    def survival(self, cause: RootCause, age: float, horizon: float) -> float:
        """Pr[T > horizon | T > age] under the cause's Kaplan-Meier curve."""
        s, _ = self._km(self._cause_samples(cause), age, horizon)
        return s

    def expected_remaining(self, cause: RootCause, age: float) -> float:
        """E[T - age | T > age]: mean remaining duration at the given age.

        Zero when every observation (prior included) is below ``age`` —
        the fault has outlived all evidence, and the caller's robustness
        cap (escalate anyway once the accumulated impact is a multiple of
        the overhead) takes over.
        """
        _, remaining = self._km(self._cause_samples(cause), age, math.inf)
        return remaining

    @staticmethod
    def _km(
        samples: list[_Sample], age: float, horizon: float
    ) -> tuple[float, float]:
        """Weighted Kaplan-Meier over samples, left-truncated at ``age``.

        Returns ``(S(horizon), integral of S from age)`` — the survival
        probability at the horizon and the restricted mean remaining
        duration. Samples are sorted ascending; only those beyond the age
        enter the risk set (conditioning on T > age). If the last sample
        is censored, the curve's leftover mass is treated as a point mass
        there (restricted mean — the standard KM convention).
        """
        tail = [s for s in samples if s.duration > age]
        if not tail:
            return 0.0, 0.0
        at_risk = sum(s.weight for s in tail)
        surv = 1.0
        remaining = 0.0
        prev = age
        i = 0
        while i < len(tail):
            t = tail[i].duration
            dead = 0.0
            here = 0.0
            while i < len(tail) and tail[i].duration == t:
                here += tail[i].weight
                if not tail[i].censored:
                    dead += tail[i].weight
                i += 1
            step = min(t, horizon) - prev
            if step > 0:
                remaining += surv * step
            if t >= horizon:
                return surv, remaining
            if at_risk > 0 and dead > 0:
                surv *= max(0.0, 1.0 - dead / at_risk)
            at_risk -= here
            prev = t
        return surv, remaining
