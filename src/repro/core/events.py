"""Event types shared by the FALCON detection/mitigation stack.

The detection pipeline is framework-agnostic (paper R1): it consumes only
streams of :class:`CommEvent` (what the paper's LD_PRELOAD shim logs) and
emits :class:`FailSlowEvent` descriptions that the mitigation planner acts on.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class CommOp(enum.Enum):
    """Collective-communication operation types the Monitor logs."""

    ALL_REDUCE = "AR"
    ALL_GATHER = "AG"
    REDUCE_SCATTER = "RS"
    ALL_TO_ALL = "A2A"
    SEND_RECV = "P2P"
    BROADCAST = "BC"


class RootCause(enum.Enum):
    """Fail-slow root causes from the characterization study (Table 1)."""

    CPU_CONTENTION = "cpu_contention"
    GPU_DEGRADATION = "gpu_degradation"
    NETWORK_CONGESTION = "network_congestion"
    UNKNOWN = "unknown"


class Strategy(enum.Enum):
    """Mitigation strategies S1-S4 (Table 3), ordered by overhead."""

    IGNORE = 1
    ADJUST_MICROBATCH = 2
    ADJUST_TOPOLOGY = 3
    CKPT_AND_RESTART = 4


#: A mitigation-strategy identifier: the paper's S1-S4 enum members, or a
#: string for strategies registered by users of the control plane (e.g.
#: "HOT_SPARE_SWAP"). The planner and the strategy registry are keyed by
#: this union so new scenarios are one class, not an enum edit.
StrategyKey = Strategy | str


def strategy_label(key: StrategyKey) -> str:
    """Human-readable name of a strategy key (enum member or string)."""
    return key.name if isinstance(key, Strategy) else str(key)


@dataclass(frozen=True)
class CommEvent:
    """One logged communication call: (type, timestamp, group, rank)."""

    op: CommOp
    timestamp: float  # seconds
    group: str = ""  # communication-group id, e.g. "dp0", "tp3"
    rank: int = 0
    duration: float = 0.0  # filled during the profiling phase (CUDA events)


@dataclass
class FailSlowEvent:
    """A detected fail-slow incident, as handed to the mitigation planner."""

    start_time: float
    root_cause: RootCause = RootCause.UNKNOWN
    #: slow component ids, e.g. GPU ranks or "link:3-4"
    components: list[str] = field(default_factory=list)
    #: healthy iteration time (s) measured before onset
    t_healthy: float = 0.0
    #: degraded iteration time (s) during the event
    t_slow: float = 0.0
    #: severity in [0, 1): relative throughput loss
    severity: float = 0.0
    #: True when the incident is a hang (unbounded slowdown): the stream
    #: stopped emitting samples and the watchdog, not BOCD, flagged it.
    #: Hangs take the abort/re-form mitigation path — micro-batch re-splits
    #: and placement swaps cannot unstick a stuck collective.
    hang: bool = False
    end_time: float | None = None  # None while ongoing

    @property
    def resolved(self) -> bool:
        return self.end_time is not None

    def duration(self, now: float) -> float:
        return (self.end_time if self.resolved else now) - self.start_time


@dataclass(frozen=True)
class ChangePoint:
    """A change-point in the iteration-time series (BOCD output)."""

    index: int
    probability: float
    #: mean iteration time before / after the change-point
    mean_before: float = 0.0
    mean_after: float = 0.0

    @property
    def relative_change(self) -> float:
        if self.mean_before <= 0.0:
            return 0.0
        return (self.mean_after - self.mean_before) / self.mean_before
