"""Ski-rental adaptive multi-level mitigation planner (paper §5.2, Alg. 1).

Start with the cheapest strategy and escalate to the next (more effective,
more costly) one once the *accumulated* fail-slow impact

    slow_impact = slow_iters * (t_slow - t_healthy)

exceeds that strategy's one-off action overhead — the ski-rental break-even
rule. S1 (ignore) has zero overhead and is always applied first; S4
(checkpoint-and-restart) is the last resort.

Predictive break-even (beyond Alg. 1)
-------------------------------------
The classic rule prices every escalation against an *infinite* rental
horizon: it pays overhead B only after suffering B of impact, and it pays
it even when the fault (or the job itself) is about to end. When the
planner is given a :class:`~repro.core.duration.DurationModel` the
break-even uses the predicted benefit instead,

    benefit = min(E[T - age | T > age], work_remaining) * residual_rate

the expected remaining fail-slow impact if nothing more is done, capped by
how much work the job has left and by the observed incident inter-arrival
time (clearing a fault only buys a healthy window until the next one
lands — under a fail-slow storm that window, not the fault's tail, bounds
what any mitigation is worth). Following ski-rental with predictions
(Purohit et al.), the prediction *replaces* the fixed horizon: the rung
fires at ``lambda * B`` when the predicted benefit clearly exceeds the
overhead (``benefit > margin * B`` — act early, the fault will outlast the
investment) and only at ``B / lambda`` otherwise (hold out — the
robustness cap that bounds the damage of a wrong prediction). The margin
matters in practice: under a fail-slow storm the predicted benefit of a
restart hovers right at its overhead, and acting on coin-flip predictions
pays the overhead over and over for healthy windows that never
materialize. With no estimator, the paper's fixed-horizon rule is
reproduced exactly.
"""
from __future__ import annotations

from collections.abc import Callable, Sequence
from dataclasses import dataclass, field

from repro.core.duration import DurationModel
from repro.core.events import FailSlowEvent, RootCause, Strategy, StrategyKey

#: Which strategies can mitigate which root cause (paper Table 3).
APPLICABLE: dict[RootCause, tuple[Strategy, ...]] = {
    RootCause.CPU_CONTENTION: (
        Strategy.IGNORE,
        Strategy.ADJUST_MICROBATCH,
        Strategy.ADJUST_TOPOLOGY,
        Strategy.CKPT_AND_RESTART,
    ),
    RootCause.GPU_DEGRADATION: (
        Strategy.IGNORE,
        Strategy.ADJUST_MICROBATCH,
        Strategy.ADJUST_TOPOLOGY,
        Strategy.CKPT_AND_RESTART,
    ),
    RootCause.NETWORK_CONGESTION: (
        Strategy.IGNORE,
        Strategy.ADJUST_TOPOLOGY,  # S2 has "No Effect" on slow comm (Table 3)
        Strategy.CKPT_AND_RESTART,
    ),
    RootCause.UNKNOWN: (
        Strategy.IGNORE,
        Strategy.ADJUST_MICROBATCH,
        Strategy.ADJUST_TOPOLOGY,
        Strategy.CKPT_AND_RESTART,
    ),
}

#: Default one-off action overheads in seconds, matching what this repo
#: measures: micro-batch solve is sub-millisecond and applies on the next
#: iteration (Table 6 / benchmarks/microbatch_solver.py — we charge 2 s for
#: the profile + swap); the memory-based topology swap is seconds (Fig. 19 /
#: benchmarks/topology_overhead.py — the paper's worst case is "within one
#: minute"); checkpoint-and-restart is tens of minutes for large models.
DEFAULT_OVERHEADS: dict[Strategy, float] = {
    Strategy.IGNORE: 0.0,
    Strategy.ADJUST_MICROBATCH: 2.0,
    Strategy.ADJUST_TOPOLOGY: 10.0,
    Strategy.CKPT_AND_RESTART: 1800.0,
}


@dataclass(frozen=True)
class PlannerKnobs:
    """The planner's externally settable break-even surface.

    One frozen bundle of every tunable the ski-rental rule exposes, so a
    control plane (or the what-if knob auto-tuner,
    :mod:`repro.whatif.tuning`) can sweep them without touching planner
    internals. Defaults reproduce the shipped behavior exactly.

    * ``prediction_lambda`` / ``prediction_margin`` — the predictive
      two-zone break-even's trust factor and required benefit/overhead
      ratio (see the module docstring).
    * ``breakeven_scale`` — a global multiplier on every rung's escalation
      threshold: < 1 escalates earlier than the classic rule (aggressive),
      > 1 holds out longer (conservative). It scales the *threshold* the
      accumulated impact is compared against, so it composes with both the
      classic and the predictive rules.
    """

    prediction_lambda: float = 0.25
    prediction_margin: float = 1.5
    breakeven_scale: float = 1.0

    def replaced(self, **overrides) -> "PlannerKnobs":
        from dataclasses import replace

        return replace(self, **overrides)


#: knob name -> (lower bound, upper bound, search on log scale) — the
#: domain the auto-tuner may explore (values outside are planner abuse)
KNOB_BOUNDS: dict[str, tuple[float, float, bool]] = {
    "prediction_lambda": (0.05, 1.0, False),
    "prediction_margin": (1.0, 3.0, False),
    "breakeven_scale": (0.25, 4.0, True),
}


def threshold_value(knobs: PlannerKnobs, rec: dict) -> float:
    """Escalation threshold of one recorded break-even consult under
    ``knobs`` — the pure function :meth:`MitigationPlanner._threshold`
    evaluates, split out so a knob bundle can be *re-scored* against a
    recorded decision trace without re-running the campaign.

    ``rec`` carries the knob-independent inputs the consult saw:
    ``overhead``, ``delta``, ``t_now``, ``hang``, and ``window`` — the
    already-resolved min of the estimator's expected remaining duration,
    the job's remaining work and the incident gap (None when the classic
    fixed-horizon rule applies: no estimator, or a zero-overhead rung).
    Every input is independent of the knob values *up to the first
    decision that differs*, which is exactly the prefix a memo needs.
    """
    scale = max(knobs.breakeven_scale, 1e-3)
    overhead = rec["overhead"]
    lam = min(max(knobs.prediction_lambda, 1e-3), 1.0)
    if rec["hang"] and overhead > 0.0:
        rate = min(rec["delta"] / max(rec["t_now"], 1e-12), 1.0)
        window = rec["window"]
        benefit = window if window == float("inf") else window * rate
        return scale * (overhead * lam if benefit > overhead else overhead)
    if rec["window"] is None:
        return scale * overhead
    rate = rec["delta"] / max(rec["t_now"], 1e-12)
    benefit = rec["window"] * rate
    margin = max(knobs.prediction_margin, 1.0)
    return scale * (
        overhead * lam if benefit > overhead * margin else overhead / lam
    )


@dataclass
class MitigationPlanner:
    """Stateful Algorithm 1 for one fail-slow event.

    Drive it with :meth:`update` once per (slow) iteration; it returns the
    strategy to apply *now*, or None. ``event.persist()`` in the paper's
    pseudocode corresponds to the caller ceasing updates once the event is
    resolved (detected by FALCON-DETECT as a relief change-point).
    """

    event: FailSlowEvent
    overheads: dict[StrategyKey, float] = field(
        default_factory=lambda: dict(DEFAULT_OVERHEADS)
    )
    #: explicit candidate ladder (e.g. from a control-plane StrategyRegistry,
    #: which may include custom string-keyed strategies). None reproduces the
    #: paper's Table 3 applicability exactly.
    candidates: Sequence[StrategyKey] | None = None
    #: per-cause fault-duration survival curves; None = the paper's fixed
    #: (infinite) ski-rental horizon
    estimator: DurationModel | None = None
    #: remaining useful work of the job in wall-clock seconds (caps the
    #: benefit any mitigation can still deliver); None = unbounded
    work_remaining: Callable[[], float] | None = None
    #: observed mean wall-clock gap between fresh incidents hitting a job
    #: (the healthy window a successful mitigation can actually buy before
    #: the next fault lands); None = unbounded
    incident_gap: Callable[[], float] | None = None
    #: prediction trust factor in (0, 1]: predicted-profitable escalations
    #: fire at lambda*B, predicted-unprofitable ones only at B/lambda.
    #: 1.0 degenerates to the classic rule even with an estimator.
    prediction_lambda: float = 0.25
    #: required benefit/overhead ratio (>= 1) before the prediction is
    #: trusted enough to escalate early
    prediction_margin: float = 1.5
    #: global multiplier on every rung's escalation threshold (see
    #: :class:`PlannerKnobs.breakeven_scale`); 1.0 = shipped behavior
    breakeven_scale: float = 1.0
    #: optional knob bundle; when given its values override the three
    #: scalar fields above (one injection point for the auto-tuner)
    knobs: PlannerKnobs | None = None
    #: optional shared sink for break-even consult records (see
    #: :func:`threshold_value`): every consult appends its knob-independent
    #: inputs plus the decision taken, so a campaign engine can re-score
    #: alternative knob bundles against the recorded trace without
    #: re-running the timeline
    trace: list | None = None

    _candidates: list[StrategyKey] = field(init=False)
    _id: int = field(init=False, default=0)
    _slow_iters: int = field(init=False, default=0)
    _impact: float = field(init=False, default=0.0)
    #: wall-clock seconds this planner has watched the event degrade
    _age: float = field(init=False, default=0.0)
    applied: list[StrategyKey] = field(init=False, default_factory=list)

    def __post_init__(self) -> None:
        if self.knobs is not None:
            self.prediction_lambda = self.knobs.prediction_lambda
            self.prediction_margin = self.knobs.prediction_margin
            self.breakeven_scale = self.knobs.breakeven_scale
        cands = (
            list(self.candidates)
            if self.candidates is not None
            else list(APPLICABLE[self.event.root_cause])
        )
        cands.sort(key=lambda s: self.overheads[s])  # stable: order tie-breaks
        self._candidates = cands

    @property
    def slow_impact(self) -> float:
        """Accumulated impact: sum over slow iterations of (t - t_healthy)."""
        return self._impact

    def update(
        self, slow_iters: float = 1, current_time: float | None = None
    ) -> StrategyKey | None:
        """Register ``slow_iters`` more degraded iterations; maybe escalate.

        ``slow_iters`` may be fractional: a fleet monitor sampling on a
        fixed cadence observes ``sample_period / iter_time`` iterations per
        sample, and the impact integral must count iterations, not samples,
        for the ski-rental break-even to be in wall-clock units.

        ``current_time`` is the *measured* iteration time now — the paper
        escalates only while "the current strategy proves ineffective", so
        the accumulated impact uses the live residual slowdown, which a
        successful mitigation drives to ~zero. Without it, the detection-time
        (t_slow - t_healthy) delta is charged, reproducing Algorithm 1
        literally.

        Returns the next strategy when the accumulated impact exceeds its
        overhead (Alg. 1 lines 13-15), else None.
        """
        if self.event.resolved or self._id >= len(self._candidates):
            return None
        self._slow_iters += slow_iters
        t_now = current_time if current_time is not None else self.event.t_slow
        self._age += slow_iters * max(t_now, 0.0)
        delta = (
            max(self.event.t_slow - self.event.t_healthy, 0.0)
            if current_time is None
            else max(current_time - self.event.t_healthy, 0.0)
        )
        # Residual within noise of healthy => current strategy is effective.
        if current_time is not None and delta < 0.05 * max(self.event.t_healthy, 1e-12):
            return None
        self._impact += slow_iters * delta
        nxt = self._candidates[self._id]
        fire = self.slow_impact > self._threshold(nxt, delta, t_now)
        if self.trace is not None:
            self.trace[-1]["decision"] = fire
        if fire:
            self._id += 1
            self.applied.append(nxt)
            return nxt
        return None

    def _threshold(self, nxt: StrategyKey, delta: float, t_now: float) -> float:
        """Escalation threshold for the next rung (see module docstring).

        Every branch's result is scaled by ``breakeven_scale``: the knob
        moves the whole break-even surface, not one rule's corner case.

        Hang events (``event.hang``) price an *unbounded* slowdown
        (multiplier → ∞): a hang never relieves itself, so the
        survival-curve query is meaningless (its huge ``_age`` would
        predict ~zero remaining duration, parking the planner in the B/λ
        hold-out forever while the job makes no progress). The benefit of
        acting caps at the job's remaining work, the hold-out zone is
        bypassed, and a non-finite benefit is treated as clearly
        profitable rather than overflowing.

        The consult's knob-independent inputs are resolved here, recorded
        on :attr:`trace` when one is attached, and priced by the pure
        :func:`threshold_value` — the same function a memo uses to
        re-score the trace under different knobs.
        """
        overhead = self.overheads[nxt]
        hang = bool(getattr(self.event, "hang", False))
        window: float | None
        if hang and overhead > 0.0:
            window = float("inf")
            if self.work_remaining is not None:
                window = min(window, max(self.work_remaining(), 0.0))
            if self.incident_gap is not None:
                window = min(window, max(self.incident_gap(), 0.0))
        elif self.estimator is None or overhead <= 0.0:
            window = None
        else:
            # Wall-clock window the fault can keep hurting us: its
            # predicted remaining duration, curtailed by the job's
            # remaining work and by the next incident's arrival.
            window = self.estimator.expected_remaining(
                self.event.root_cause, self._age
            )
            if self.work_remaining is not None:
                window = min(window, max(self.work_remaining(), 0.0))
            if self.incident_gap is not None:
                window = min(window, max(self.incident_gap(), 0.0))
        rec = {
            "overhead": overhead,
            "delta": delta,
            "t_now": t_now,
            "hang": hang,
            "window": window,
        }
        if self.trace is not None:
            rec["impact"] = self._impact
            rec["strategy"] = nxt
            rec["decision"] = False
            self.trace.append(rec)
        knobs = PlannerKnobs(
            prediction_lambda=self.prediction_lambda,
            prediction_margin=self.prediction_margin,
            breakeven_scale=self.breakeven_scale,
        )
        return threshold_value(knobs, rec)

    def exhausted(self) -> bool:
        return self._id >= len(self._candidates)
