"""Ski-rental adaptive multi-level mitigation planner (paper §5.2, Alg. 1).

Start with the cheapest strategy and escalate to the next (more effective,
more costly) one once the *accumulated* fail-slow impact

    slow_impact = slow_iters * (t_slow - t_healthy)

exceeds that strategy's one-off action overhead — the ski-rental break-even
rule. S1 (ignore) has zero overhead and is always applied first; S4
(checkpoint-and-restart) is the last resort.
"""
from __future__ import annotations

from collections.abc import Sequence
from dataclasses import dataclass, field

from repro.core.events import FailSlowEvent, RootCause, Strategy, StrategyKey

#: Which strategies can mitigate which root cause (paper Table 3).
APPLICABLE: dict[RootCause, tuple[Strategy, ...]] = {
    RootCause.CPU_CONTENTION: (
        Strategy.IGNORE,
        Strategy.ADJUST_MICROBATCH,
        Strategy.ADJUST_TOPOLOGY,
        Strategy.CKPT_AND_RESTART,
    ),
    RootCause.GPU_DEGRADATION: (
        Strategy.IGNORE,
        Strategy.ADJUST_MICROBATCH,
        Strategy.ADJUST_TOPOLOGY,
        Strategy.CKPT_AND_RESTART,
    ),
    RootCause.NETWORK_CONGESTION: (
        Strategy.IGNORE,
        Strategy.ADJUST_TOPOLOGY,  # S2 has "No Effect" on slow comm (Table 3)
        Strategy.CKPT_AND_RESTART,
    ),
    RootCause.UNKNOWN: (
        Strategy.IGNORE,
        Strategy.ADJUST_MICROBATCH,
        Strategy.ADJUST_TOPOLOGY,
        Strategy.CKPT_AND_RESTART,
    ),
}

#: Default one-off action overheads in seconds, matching what this repo
#: measures: micro-batch solve is sub-millisecond and applies on the next
#: iteration (Table 6 / benchmarks/microbatch_solver.py — we charge 2 s for
#: the profile + swap); the memory-based topology swap is seconds (Fig. 19 /
#: benchmarks/topology_overhead.py — the paper's worst case is "within one
#: minute"); checkpoint-and-restart is tens of minutes for large models.
DEFAULT_OVERHEADS: dict[Strategy, float] = {
    Strategy.IGNORE: 0.0,
    Strategy.ADJUST_MICROBATCH: 2.0,
    Strategy.ADJUST_TOPOLOGY: 10.0,
    Strategy.CKPT_AND_RESTART: 1800.0,
}


@dataclass
class MitigationPlanner:
    """Stateful Algorithm 1 for one fail-slow event.

    Drive it with :meth:`update` once per (slow) iteration; it returns the
    strategy to apply *now*, or None. ``event.persist()`` in the paper's
    pseudocode corresponds to the caller ceasing updates once the event is
    resolved (detected by FALCON-DETECT as a relief change-point).
    """

    event: FailSlowEvent
    overheads: dict[StrategyKey, float] = field(
        default_factory=lambda: dict(DEFAULT_OVERHEADS)
    )
    #: explicit candidate ladder (e.g. from a control-plane StrategyRegistry,
    #: which may include custom string-keyed strategies). None reproduces the
    #: paper's Table 3 applicability exactly.
    candidates: Sequence[StrategyKey] | None = None

    _candidates: list[StrategyKey] = field(init=False)
    _id: int = field(init=False, default=0)
    _slow_iters: int = field(init=False, default=0)
    _impact: float = field(init=False, default=0.0)
    applied: list[StrategyKey] = field(init=False, default_factory=list)

    def __post_init__(self) -> None:
        cands = (
            list(self.candidates)
            if self.candidates is not None
            else list(APPLICABLE[self.event.root_cause])
        )
        cands.sort(key=lambda s: self.overheads[s])  # stable: order tie-breaks
        self._candidates = cands

    @property
    def slow_impact(self) -> float:
        """Accumulated impact: sum over slow iterations of (t - t_healthy)."""
        return self._impact

    def update(
        self, slow_iters: float = 1, current_time: float | None = None
    ) -> StrategyKey | None:
        """Register ``slow_iters`` more degraded iterations; maybe escalate.

        ``slow_iters`` may be fractional: a fleet monitor sampling on a
        fixed cadence observes ``sample_period / iter_time`` iterations per
        sample, and the impact integral must count iterations, not samples,
        for the ski-rental break-even to be in wall-clock units.

        ``current_time`` is the *measured* iteration time now — the paper
        escalates only while "the current strategy proves ineffective", so
        the accumulated impact uses the live residual slowdown, which a
        successful mitigation drives to ~zero. Without it, the detection-time
        (t_slow - t_healthy) delta is charged, reproducing Algorithm 1
        literally.

        Returns the next strategy when the accumulated impact exceeds its
        overhead (Alg. 1 lines 13-15), else None.
        """
        if self.event.resolved or self._id >= len(self._candidates):
            return None
        self._slow_iters += slow_iters
        delta = (
            max(self.event.t_slow - self.event.t_healthy, 0.0)
            if current_time is None
            else max(current_time - self.event.t_healthy, 0.0)
        )
        # Residual within noise of healthy => current strategy is effective.
        if current_time is not None and delta < 0.05 * max(self.event.t_healthy, 1e-12):
            return None
        self._impact += slow_iters * delta
        nxt = self._candidates[self._id]
        if self.slow_impact > self.overheads[nxt]:
            self._id += 1
            self.applied.append(nxt)
            return nxt
        return None

    def exhausted(self) -> bool:
        return self._id >= len(self._candidates)
