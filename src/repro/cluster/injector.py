"""Deterministic fail-slow injection (paper §7.1).

The paper injects computation fail-slows by locking GPU SM frequency
(`nvidia-smi -lgc`) and communication fail-slows with side-channel bandwidth
contention. Here the same injections are applied to the simulator's
:class:`ClusterState`: compute multipliers for GPU degradation, host
multipliers for CPU contention (hits every GPU on the node), and link
bandwidth multipliers for congestion.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field

import numpy as np

from repro.cluster.spec import ClusterState


class InjectionKind(enum.Enum):
    GPU_SLOW = "gpu_slow"  # one device's SMs throttled
    CPU_CONTENTION = "cpu_contention"  # whole node slowed
    LINK_CONGESTION = "link_congestion"  # one physical link degraded
    NIC_CONGESTION = "nic_congestion"  # a node's NIC port congested
    GPU_HANG = "gpu_hang"  # a device stops making progress (hardware)
    COLLECTIVE_HANG = "collective_hang"  # a collective stuck on a link


#: hang kinds keep the math finite: instead of an infinite multiplier, a
#: hung component runs at this fraction of its healthy speed (~10⁶× slow),
#: far past any throttle — the simulator's stall test keys off the ratio.
HANG_EPS = 1e-6

#: the hang fault family (near-infinite slowdown; severity is metadata)
HANG_KINDS = frozenset({InjectionKind.GPU_HANG, InjectionKind.COLLECTIVE_HANG})


@dataclass(frozen=True)
class Injection:
    """One fail-slow episode.

    ``severity`` in (0, 1): fraction of performance lost. A GPU_SLOW of 0.3
    runs the GPU at 70 % speed; LINK_CONGESTION of 0.75 leaves 25 % of the
    bandwidth (the paper's weak/medium/severe ~= 0.2/0.5/0.8). ``ramp`` > 0
    builds the severity up linearly over that many seconds from onset —
    network congestion typically has a gradual onset (§3), the failure mode
    fixed-offset window detectors miss.

    Hang kinds (``GPU_HANG`` / ``COLLECTIVE_HANG``) ignore ``severity`` and
    ``ramp``: the affected component drops to :data:`HANG_EPS` of its speed
    for the whole episode (a hang has no partial tier and no gradual onset).
    ``scope`` optionally names the collective a ``COLLECTIVE_HANG`` is stuck
    in ("dp" / "tp" / "pp"); it is diagnostic metadata only.
    """

    start: float  # wall-clock seconds
    duration: float
    kind: InjectionKind
    target: tuple[int, ...]  # (device,) / (node,) / (devA, devB)
    severity: float
    ramp: float = 0.0  # seconds from onset to full severity (0 = step)
    scope: str = ""  # optional collective scope for hangs ("dp"/"tp"/"pp")

    @property
    def end(self) -> float:
        return self.start + self.duration

    def active(self, now: float) -> bool:
        return self.start <= now < self.end

    def severity_at(self, now: float) -> float:
        """Effective severity at ``now`` (0 outside the episode)."""
        if not self.active(now):
            return 0.0
        if self.ramp <= 0.0:
            return self.severity
        return self.severity * min(1.0, (now - self.start) / self.ramp)


@dataclass
class FailSlowInjector:
    """Applies the set of active injections to a ClusterState at time t.

    Schedule mutation contract: change ``injections`` only through
    :meth:`add` / :meth:`extend` or by *reassigning the whole list* (the
    S4 restart-clearing pattern, ``injector.injections = [...]``) — all
    three bump ``epoch``, which schedule consumers (the campaign runner's
    per-job fault cursors) rely on to detect staleness. Mutating the list
    in place (``injections.append(...)``) bypasses the epoch and those
    consumers will silently never re-apply.
    """

    injections: list[Injection] = field(default_factory=list)
    _last_applied: tuple | None = field(init=False, default=None)
    #: last-applied per-component multipliers, keyed by
    #: ("c", dev) / ("h", dev) / ("l", (lo, hi)) / ("n", node)
    _applied_vals: dict | None = field(init=False, default=None, repr=False)

    def __setattr__(self, name: str, value) -> None:
        object.__setattr__(self, name, value)
        if name == "injections":
            # Schedule identity epoch: consumers holding a cursor over this
            # injector (campaign per-job fault cursors) must re-apply after
            # any wholesale reassignment — S4 clears active episodes this
            # way when a restart escapes onto healthy hardware.
            d = self.__dict__
            d["epoch"] = d.get("epoch", 0) + 1

    def add(self, inj: Injection) -> None:
        self.injections.append(inj)
        self.epoch += 1

    def extend(self, injections: list[Injection]) -> "FailSlowInjector":
        """Compose another schedule onto this injector (campaign layering:
        a preset's fixed episodes plus a sampled fault-model schedule)."""
        self.injections.extend(injections)
        self.epoch += 1
        return self

    def active(self, now: float) -> list[Injection]:
        return [i for i in self.injections if i.active(now)]

    def _target_values(
        self, state: ClusterState, act: list[Injection], severities
    ) -> dict:
        """Composed per-component multipliers of the active set.

        Multiplication runs in episode order, exactly the chain the
        sequential ``*=`` reapply used to produce — overlapping episodes on
        one target compose (two 0.5-severity GPU throttles leave 25 % of
        the speed), and when the earlier episode ends the later one's
        degradation, not full health, is what remains.
        """
        vals: dict = {}
        per = state.spec.gpus_per_node
        for inj, severity in zip(act, severities):
            mult = HANG_EPS if inj.kind in HANG_KINDS else 1.0 - severity
            if inj.kind in (InjectionKind.GPU_SLOW, InjectionKind.GPU_HANG):
                (dev,) = inj.target
                k = ("c", dev)
                vals[k] = vals.get(k, 1.0) * mult
            elif inj.kind is InjectionKind.CPU_CONTENTION:
                (node,) = inj.target
                for d in range(node * per, (node + 1) * per):
                    k = ("h", d)
                    vals[k] = vals.get(k, 1.0) * mult
            elif inj.kind is InjectionKind.NIC_CONGESTION:
                (node,) = inj.target
                k = ("n", node)
                vals[k] = vals.get(k, 1.0) * mult
            else:
                a, b = inj.target
                k = ("l", (min(a, b), max(a, b)))
                vals[k] = vals.get(k, 1.0) * mult
        return vals

    @staticmethod
    def _write(state: ClusterState, k, v: float) -> None:
        kind, ident = k
        if kind == "c":
            state.devices[ident].compute_speed = v
        elif kind == "h":
            state.devices[ident].host_speed = v
        elif kind == "n":
            state.nic_mult[ident] = v
        else:
            state.link_mult[ident] = v

    @staticmethod
    def _restore(state: ClusterState, k) -> None:
        kind, ident = k
        if kind == "c":
            state.devices[ident].compute_speed = 1.0
        elif kind == "h":
            state.devices[ident].host_speed = 1.0
        elif kind == "n":
            state.nic_mult.pop(ident, None)
        else:
            state.link_mult.pop(ident, None)

    def apply(self, state: ClusterState, now: float) -> list[Injection]:
        """Bring ``state`` to the set of injections active at ``now``.

        Steady state is O(1): when the active set and its effective
        severities are unchanged since the last apply *and* nobody else
        mutated the state (checked through its version counter), nothing is
        touched and the simulator's memoized iteration time survives.

        On a transition (an episode starting, ending, or ramping), the new
        per-component target multipliers are *diffed* against what this
        injector last wrote: only components whose value actually changed
        are written (and components whose episodes all ended are restored),
        so the state's mutation log — and therefore the simulator's
        incremental recompute — stays scoped to the event instead of a
        whole-state reset+reapply. If anyone else mutated the state since
        our last apply, the diff basis is void and the pre-refactor
        reset+reapply runs (same final multipliers either way, since the
        diff writes the identical composed products).
        """
        act = self.active(now)
        severities = tuple(i.severity_at(now) for i in act)
        key = (id(state), tuple(act), severities, state.version)
        if self._last_applied == key:
            return act
        new_vals = self._target_values(state, act, severities)
        prev = self._applied_vals
        if (
            prev is not None
            and self._last_applied is not None
            and self._last_applied[0] == id(state)
            and self._last_applied[3] == state.version
        ):
            # Diff basis valid: the state is exactly what we last wrote.
            for k in prev.keys() - new_vals.keys():
                self._restore(state, k)
            for k, v in new_vals.items():
                if prev.get(k) != v:
                    self._write(state, k, v)
        else:
            state.reset()
            for k, v in new_vals.items():
                self._write(state, k, v)
        self._applied_vals = new_vals
        self._last_applied = (id(state), tuple(act), severities, state.version)
        return act


def sample_injections(
    rng: np.random.Generator,
    n_devices: int,
    gpus_per_node: int,
    horizon: float,
    *,
    p_gpu: float = 0.005,
    p_cpu: float = 0.01,
    p_link: float = 0.4,
    mean_comp_duration: float = 600.0,
    mean_comm_duration: float = 1440.0,
) -> list[Injection]:
    """Sample a fail-slow workload matching the characterization stats (§3):

    computation fail-slows are rare and short (mean ~10 min), communication
    fail-slows (congestion) frequent and long (mean ~24 min); probabilities
    are per-job occurrence rates from Table 1.
    """
    out: list[Injection] = []
    if rng.random() < p_gpu:
        dev = int(rng.integers(n_devices))
        out.append(
            Injection(
                start=float(rng.uniform(0, horizon)),
                duration=float(rng.exponential(mean_comp_duration)),
                kind=InjectionKind.GPU_SLOW,
                target=(dev,),
                severity=float(rng.uniform(0.15, 0.5)),
            )
        )
    if rng.random() < p_cpu:
        node = int(rng.integers(max(1, n_devices // gpus_per_node)))
        out.append(
            Injection(
                start=float(rng.uniform(0, horizon)),
                duration=float(rng.exponential(mean_comp_duration)),
                kind=InjectionKind.CPU_CONTENTION,
                target=(node,),
                severity=float(rng.uniform(0.1, 0.3)),
            )
        )
    if n_devices > gpus_per_node and rng.random() < p_link:
        a = int(rng.integers(n_devices))
        other_nodes = [
            n for n in range(n_devices // gpus_per_node) if n != a // gpus_per_node
        ]
        node_b = int(rng.choice(other_nodes))
        b = node_b * gpus_per_node + int(rng.integers(gpus_per_node))
        out.append(
            Injection(
                start=float(rng.uniform(0, horizon)),
                duration=float(rng.exponential(mean_comm_duration)),
                kind=InjectionKind.LINK_CONGESTION,
                target=(a, b),
                severity=float(rng.uniform(0.3, 0.85)),
            )
        )
    return out
