"""Hybrid-parallel training-iteration performance model.

Models one training job under (TP, DP, PP) hybrid parallelism on a
:class:`ClusterState`, with 1F1B pipelining, ring collectives, per-DP-group
micro-batch counts (S2), and a logical->physical placement permutation (S3).
It implements the :class:`repro.core.detector.ClusterInterface` protocol so
FALCON-DETECT runs against it unchanged, and emits the same CommEvent
stream the Monitor shim would log on a real job.

The model intentionally follows the paper's own cost reasoning
(Appendix 9.2): compute time = FLOPs / effective speed; collective time =
ring volume / slowest link; pipeline time = (m + P - 1) x slowest stage.

Fast-path architecture (fleet scale)
------------------------------------
``iteration_time()`` / ``profile_groups()`` / ``per_microbatch_times()``
run on a vectorized core instead of the original nested Python loops:

* A per-placement :class:`_Layout` precomputes the (pp, dp, tp) device-index
  grid, the ring-edge endpoint arrays of every TP cell and DP ring, the PP
  hop endpoints and the profiling-group key strings. It is rebuilt only when
  the placement (or job/cluster) changes.
* Per-cell partial reductions are cached in :class:`_Cells` (cell speed
  minima, per-edge ring bandwidths and their ring minima, hop bandwidths,
  derived stage times) aligned with the ``_Layout`` index tensors.
* Invalidation is *event-scoped*: the simulator holds a cursor into its
  :class:`~repro.cluster.spec.ClusterState`'s typed mutation log and
  re-reduces only what a :class:`~repro.cluster.spec.DirtySet` touches —
  device dirt refreshes one cell's speed/stage, link dirt only the ring/hop
  edges that traverse that link, NIC dirt the port's cross-node incident
  edges, and ``remap_groups`` only the cells whose membership changed.
  A single fail-slow event therefore costs O(dirty cells), not O(devices);
  see docs/simulator.md for the full contract.
* Results are memoized on top: ``ClusterState.version`` covers every health
  mutation (device-speed writes, link/NIC multiplier changes, ``reset``),
  and the simulator bumps an internal config version whenever
  ``placement``/``allocation``/``state`` are reassigned (including through
  ``set_allocation``/``apply_placement``/``restart``). Healthy steps
  between fail-slow events therefore cost O(1); mutate state only through
  those surfaces (lists must be *reassigned*, not edited in place).
  Reassigning ``placement``/``state``/``job``/``cluster`` wholesale drops
  the cell cache (full rebuild on next evaluation — the pre-refactor cost);
  ``sim.incremental = False`` forces that mode permanently (benchmarks).

The original loop implementations remain as ``*_reference()`` methods; the
fast path matches them bit for bit (equivalence-tested), so benchmark
results are unchanged at lower wall-clock.
"""
from __future__ import annotations

import sys
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.events import CommEvent, CommOp
from repro.core.topology import HybridTopology
from repro.cluster.spec import ClusterSpec, ClusterState, DirtySet, ModelSpec
from repro.obs.collectives import CollectiveBreakdown, decompose, timing_decomposition


@dataclass
class JobSpec:
    """One hybrid-parallel training job."""

    model: ModelSpec
    tp: int
    dp: int
    pp: int
    micro_batches: int  # M, per iteration (global batch / micro-batch size)

    @property
    def topology(self) -> HybridTopology:
        return HybridTopology(tp=self.tp, dp=self.dp, pp=self.pp)

    @property
    def n_devices(self) -> int:
        return self.tp * self.dp * self.pp


class _Layout:
    """Placement-derived index tensors, built once per placement.

    ``grid[s, d, k]`` is the physical device at (stage, dp_rank, tp_rank);
    the flattened ring-edge endpoint arrays feed ``link_bw_many`` gathers.
    """

    def __init__(self, placement: list[int], job: JobSpec) -> None:
        self.tp_keys = [
            f"tp:s{s}d{d}" for s in range(job.pp) for d in range(job.dp)
        ]
        self.dp_keys = [
            f"dp:s{s}t{k}" for s in range(job.pp) for k in range(job.tp)
        ]
        self.update(placement, job)

    def update(self, placement: list[int], job: JobSpec) -> None:
        """Refresh the index tensors for a new placement *in place*.

        The incremental rebuild path for :meth:`TrainingSimulator.
        remap_groups`: the group-key strings (the expensive part of a full
        build, and placement-independent) survive; only the device grid and
        the ring/hop endpoint gathers are recomputed — O(devices) array
        work with no Python-level string formatting.
        """
        flat = np.asarray(placement, dtype=np.int64)
        grid = flat.reshape(job.pp, job.dp, job.tp)
        self.grid = grid
        #: inverse index: physical device -> flat logical position (-1 =
        #: device not used by this job); dirty components map through it to
        #: the (stage, dp, tp) cells incremental recomputation must touch
        self.dev_pos = np.full(int(flat.max()) + 1, -1, dtype=np.int64)
        self.dev_pos[flat] = np.arange(flat.size, dtype=np.int64)
        self.tp_edges = None
        self.dp_edges = None
        self.hop_edges = None
        if job.tp > 1:
            self.tp_edges = (
                grid.reshape(-1), np.roll(grid, -1, axis=2).reshape(-1)
            )
        if job.dp > 1:
            self.dp_edges = (
                grid.reshape(-1), np.roll(grid, -1, axis=1).reshape(-1)
            )
        if job.pp > 1:
            self.hop_edges = (
                grid[:-1, :, 0].reshape(-1), grid[1:, :, 0].reshape(-1)
            )
        #: lazy node -> incident cross-node edge index per edge class,
        #: built on the first NIC-scoped dirty update for this placement
        self.nic_index = None
        #: lazy node -> :class:`_NodeNic` (per-node precomputed incidence:
        #: fused endpoint gathers, ring groupings, touched cells/columns),
        #: so a repeat NIC event on a node costs zero index arithmetic
        self.nic_cache: dict = {}

    def build_nic_index(self, per: int) -> dict:
        """node -> flat ids of the cross-node edges touching it, per edge
        class (sorted-by-node arrays for searchsorted range queries)."""

        def index(edges):
            if edges is None:
                return None
            a, b = edges
            na = a // per
            nb = b // per
            cross = np.flatnonzero(na != nb)
            nodes = np.concatenate([na[cross], nb[cross]])
            ids = np.concatenate([cross, cross])
            order = np.argsort(nodes, kind="stable")
            return nodes[order], ids[order]

        self.nic_index = {
            "tp": index(self.tp_edges),
            "dp": index(self.dp_edges),
            "hop": index(self.hop_edges),
        }
        return self.nic_index

    def node_nic(self, node: int, per: int) -> "_NodeNic | None":
        """The node's precomputed NIC-dirt incidence (None when no cached
        edge crosses it), built once per (placement, node) and memoized —
        the per-event NIC path then does no searchsorted/unique work."""
        ent = self.nic_cache.get(node, False)
        if ent is not False:
            return ent
        idx = self.nic_index or self.build_nic_index(per)
        pp, dp, tp = self.grid.shape
        span = dp * tp
        seg_a: list[np.ndarray] = []
        seg_b: list[np.ndarray] = []

        def ids_of(cls, edges):
            pair = idx[cls]
            if pair is None:
                return None
            nodes_arr, eids = pair
            lo = np.searchsorted(nodes_arr, node)
            hi = np.searchsorted(nodes_arr, node + 1)
            if lo == hi:
                return None
            ids = eids[lo:hi].copy()
            seg_a.append(edges[0][ids])
            seg_b.append(edges[1][ids])
            return ids

        tp_ids = ids_of("tp", self.tp_edges)
        dp_ids = ids_of("dp", self.dp_edges)
        hop_ids = ids_of("hop", self.hop_edges)
        if tp_ids is None and dp_ids is None and hop_ids is None:
            self.nic_cache[node] = None
            return None
        ent = _NodeNic()
        ent.a = np.concatenate(seg_a)
        ent.b = np.concatenate(seg_b)
        n_tp = 0 if tp_ids is None else tp_ids.size
        n_dp = 0 if dp_ids is None else dp_ids.size
        ent.off_dp = n_tp
        ent.off_hop = n_tp + n_dp
        ent.tp_ids = tp_ids
        ent.dp_ids = dp_ids
        ent.hop_ids = hop_ids
        if tp_ids is not None:
            cf = np.unique(tp_ids // tp)
            ent.tp_cells = list(zip((cf // dp).tolist(), (cf % dp).tolist()))
        if hop_ids is not None:
            ent.hop_cols = np.unique(hop_ids % dp).tolist()
        if dp_ids is not None:
            # Group the node's DP edges by ring (stage, tp_rank): the
            # argmin fast path compares each touched ring's candidate
            # minimum against the cached bottleneck in O(touched edges).
            rings = (dp_ids // span) * tp + dp_ids % tp
            order = np.argsort(rings, kind="stable")
            rsorted = rings[order]
            starts = np.flatnonzero(
                np.r_[True, rsorted[1:] != rsorted[:-1]]
            )
            uniq = rsorted[starts]
            widths = np.diff(np.r_[starts, rings.size])
            ent.ring_s = uniq // tp
            ent.ring_k = uniq % tp
            ent.dp_order = order
            dpos = (dp_ids // tp) % dp  # edge position within its ring
            w = int(widths.max())
            ent.uniform = bool(widths.min() == w)
            if ent.uniform:
                ent.dp_width = w
                ent.dp_dpos2 = dpos[order].reshape(uniq.size, w)
                ent.dp_rows = np.arange(uniq.size)
        self.nic_cache[node] = ent
        return ent


class _NodeNic:
    """Per-(placement, node) NIC-dirt incidence (see ``_Layout.node_nic``).

    ``a``/``b`` are the fused endpoint arrays of every cached cross-node
    edge touching the node, ordered [tp | dp | hop] with class offsets
    ``off_dp``/``off_hop``, so one ``link_bw_many`` call re-measures them
    all. The dp fields group the node's DP-ring edges by ring for the
    argmin fast path (``uniform`` marks equal edges-per-ring, the common
    topology, enabling the reshaped vectorized compare)."""

    __slots__ = (
        "a", "b", "off_dp", "off_hop", "tp_ids", "dp_ids", "hop_ids",
        "tp_cells", "hop_cols", "ring_s", "ring_k", "dp_order", "uniform",
        "dp_width", "dp_dpos2", "dp_rows",
    )

    def __init__(self) -> None:
        self.tp_ids = self.dp_ids = self.hop_ids = None
        self.tp_cells: list = []
        self.hop_cols: list = []
        self.uniform = False


class _Cells:
    """Per-cell partial reductions over the current placement and state.

    ``cell_speed[s, d]`` is the slowest effective device speed of TP cell
    (stage, dp_rank); ``tp_edge``/``dp_edge`` hold every ring edge's
    bandwidth (shape ``(pp, dp, tp)``; edge ``k`` of a TP cell connects tp
    ranks ``k -> k+1``, edge ``d`` of a DP ring connects dp ranks
    ``d -> d+1``), with ``tp_bw``/``dp_bw`` their per-cell / per-ring
    minima; ``hop_bw[s, d]`` the stage-``s``→``s+1`` activation-hop
    bandwidth of DP rank ``d``; ``stage[s, d]`` the derived one-micro-batch
    stage time. These are exactly the O(devices) gather+reduce products of
    the vectorized pass — everything downstream is O(cells). A
    :class:`~repro.cluster.spec.DirtySet` maps through the layout's inverse
    index to positions, then to the incident edges and containing
    cells/rings, so a fail-slow event re-reduces only what it touches (see
    docs/simulator.md).
    """

    __slots__ = (
        "cell_speed", "tp_edge", "tp_bw", "dp_edge", "dp_bw", "hop_bw",
        "stage", "stage_max", "hop2",
        # lazy per-ring argmin over the DP axis, shape (pp, tp): dp_arg[s,k]
        # is the ring position attaining dp_bw[s,k], -1 = unknown. Built on
        # demand by the NIC fast path (None until then) and *invalidated*,
        # not maintained, by the other update paths, so they pay nothing.
        "dp_arg",
        # job-constant formula terms, factored once per build so the scalar
        # update paths replay the exact arithmetic of the array formulas
        "c_flops", "c_speed", "c_tp", "pp_vol", "c_dp",
    )


@dataclass
class TrainingSimulator:
    """Iteration-time model + FALCON ClusterInterface implementation."""

    #: event-scoped invalidation switch (class-level; set ``sim.incremental
    #: = False`` to force the pre-dirty-set behavior of one full vectorized
    #: recompute per state mutation — kept for benchmarking the two paths)
    incremental = True

    cluster: ClusterSpec
    job: JobSpec
    #: logical position p (HybridTopology order) -> physical device perm[p]
    placement: list[int] = field(default_factory=list)
    #: per-DP-group micro-batch counts (S2); default: even split
    allocation: list[int] = field(default_factory=list)
    #: reduction backend: "auto" (pallas on a compiled jax backend, else
    #: the inline vectorized numpy path), a registry name ("reference" /
    #: "vectorized" / "pallas"), or a ReductionBackend instance — see
    #: REDUCTION_BACKENDS and docs/kernels.md
    reduction: object = "auto"
    state: ClusterState = field(init=False)

    def __post_init__(self) -> None:
        if self.job.n_devices > self.cluster.n_devices:
            raise ValueError("job does not fit on the cluster")
        if not self.placement:
            self.placement = list(range(self.job.n_devices))
        if not self.allocation:
            base, extra = divmod(self.job.micro_batches, self.job.dp)
            self.allocation = [
                base + (1 if i < extra else 0) for i in range(self.job.dp)
            ]
        self.state = ClusterState(self.cluster)

    # ------------------------------------------------- memo bookkeeping
    def __setattr__(self, name: str, value) -> None:
        object.__setattr__(self, name, value)
        d = self.__dict__
        if name in ("placement", "job", "cluster"):
            d["_place_ver"] = d.get("_place_ver", 0) + 1
        if name in ("placement", "allocation", "state", "job", "cluster",
                    "reduction"):
            d["_cfg_ver"] = d.get("_cfg_ver", 0) + 1
        if name == "reduction":
            d["_red_obj"] = False  # unresolved; None = inline vectorized
        if name in ("allocation", "job"):
            d["_alloc_arr"] = None  # caches allocation + pp - 1
        if name in ("job", "cluster"):
            d["_healthy_cache"] = None  # healthy time depends only on these

    def _layout(self) -> _Layout:
        d = self.__dict__
        if d.get("_layout_ver") != d["_place_ver"]:
            d["_layout_cache"] = _Layout(self.placement, self.job)
            d["_layout_ver"] = d["_place_ver"]
        return d["_layout_cache"]

    # ------------------------------------------------------------- layout
    def device_at(self, stage: int, dp_rank: int, tp_rank: int) -> int:
        return self.placement[self.job.topology.position(stage, dp_rank, tp_rank)]

    def _cell_devices(self, stage: int, dp_rank: int) -> list[int]:
        return [self.device_at(stage, dp_rank, k) for k in range(self.job.tp)]

    # --------------------------------------------- vectorized fast path
    def _stage_from(self, cell_speed, tp_bw):
        """The (pp, dp)-shaped stage-time formula — one chain of elementwise
        ops, applied identically to the full arrays (rebuild) and to dirty
        sub-slices (incremental update), so both paths agree bit for bit."""
        m = self.job.model
        compute = (
            m.flops_per_microbatch() / self.job.pp
        ) / (self.job.tp * self.cluster.gpu_flops * cell_speed)
        if tp_bw is not None:
            tp_vol = m.comm_tp_bytes(self.job.tp, self.job.pp, 1)
            compute += 2.0 * (self.job.tp - 1) / self.job.tp * tp_vol / tp_bw
        return compute

    def _cells_rebuild(self, lay: _Layout) -> _Cells:
        """Full vectorized pass: every per-cell reduction from scratch."""
        state = self.state
        job = self.job
        m = job.model
        pp, dp, tp = job.pp, job.dp, job.tp
        c = _Cells()
        c.cell_speed = state.effective_speeds()[lay.grid].min(axis=2)
        c.tp_edge = c.tp_bw = c.dp_edge = c.dp_bw = c.hop_bw = None
        if lay.tp_edges is not None:
            c.tp_edge = state.link_bw_many(*lay.tp_edges).reshape(pp, dp, tp)
            c.tp_bw = c.tp_edge.min(axis=2)
        if lay.dp_edges is not None:
            c.dp_edge = state.link_bw_many(*lay.dp_edges).reshape(pp, dp, tp)
            c.dp_bw = c.dp_edge.min(axis=1)
        c.dp_arg = None
        if lay.hop_edges is not None:
            c.hop_bw = state.link_bw_many(*lay.hop_edges).reshape(pp - 1, dp)
        c.stage = self._stage_from(c.cell_speed, c.tp_bw)
        c.stage_max = c.stage.max(axis=0)
        # Factored formula terms: each is the exact left-to-right prefix of
        # the corresponding array expression, so the scalar update paths
        # reproduce the same float chains.
        c.c_flops = m.flops_per_microbatch() / pp
        c.c_speed = tp * self.cluster.gpu_flops
        c.c_tp = (
            2.0 * (tp - 1) / tp * m.comm_tp_bytes(tp, pp, 1)
            if c.tp_bw is not None else 0.0
        )
        c.pp_vol = m.comm_pp_bytes(1)
        c.c_dp = 2.0 * (dp - 1) / dp * m.comm_dp_bytes(tp, pp)
        c.hop2 = (
            0.0 if c.hop_bw is None
            else 2.0 * (c.pp_vol / c.hop_bw).sum(axis=0)
        )
        return c

    def _apply_dirty(self, cache: _Cells, lay: _Layout, ds) -> None:
        """Event-scoped cache refresh from a typed
        :class:`~repro.cluster.spec.DirtySet`.

        Device dirt re-reduces only the containing cell's speed minimum and
        stage time (edge bandwidths do not depend on device speeds); link
        dirt re-measures only the cached ring/hop edges that actually
        traverse that physical link (a degraded link no ring uses costs
        nothing — the same observability rule the campaign's impact filter
        applies); NIC dirt re-measures the node's devices' incident
        *cross-node* edges (intra-node edges carry no NIC factor). All
        refreshed entries replay the full pass's exact operation chains.
        """
        state = self.state
        pp, dp, tp = self.job.pp, self.job.dp, self.job.tp
        grid = lay.grid
        dev_pos = lay.dev_pos
        span = dp * tp
        cell_dirty: set[tuple[int, int]] = set()   # cell_speed changed
        tp_e: set[tuple[int, int, int]] = set()
        dp_e: set[tuple[int, int, int]] = set()
        hop_e: set[tuple[int, int]] = set()

        def pos_of(dev: int) -> int | None:
            if 0 <= dev < dev_pos.size:
                p = dev_pos[dev]
                if p >= 0:
                    return int(p)
            return None

        for dev in ds.devices:
            p = pos_of(dev)
            if p is not None:
                s, r = divmod(p, span)
                cell_dirty.add((s, r // tp))
        for a, b in ds.links:
            pa, pb = pos_of(a), pos_of(b)
            if pa is None or pb is None:
                continue
            sa, ra = divmod(pa, span)
            sb, rb = divmod(pb, span)
            da, ka = divmod(ra, tp)
            db, kb = divmod(rb, tp)
            if sa == sb:
                if da == db and cache.tp_edge is not None:
                    if (ka + 1) % tp == kb:
                        tp_e.add((sa, da, ka))
                    if (kb + 1) % tp == ka:
                        tp_e.add((sa, da, kb))
                if ka == kb and cache.dp_edge is not None:
                    if (da + 1) % dp == db:
                        dp_e.add((sa, da, ka))
                    if (db + 1) % dp == da:
                        dp_e.add((sa, db, ka))
            elif (
                cache.hop_bw is not None
                and ka == 0 and kb == 0 and da == db
                and abs(sa - sb) == 1
            ):
                hop_e.add((min(sa, sb), da))
        tp_cells: set[tuple[int, int]] = set()
        dp_rings: set[tuple[int, int]] = set()
        hop_cols: set[int] = set()
        if ds.nics:
            # Node-scoped dirt: every incident cross-node edge (only those
            # carry the NIC factor) is precomputed per node in the layout's
            # _NodeNic cache, so a repeat event re-measures them in ONE
            # fused link_bw_many call and updates the touched DP rings via
            # the argmin fast path — no per-event index arithmetic.
            per = state.spec.gpus_per_node
            for node in ds.nics:
                ent = lay.node_nic(node, per)
                if ent is None:
                    continue
                bw = state.link_bw_many(ent.a, ent.b)
                if ent.tp_ids is not None and cache.tp_edge is not None:
                    cache.tp_edge.reshape(-1)[ent.tp_ids] = bw[:ent.off_dp]
                    tp_cells.update(ent.tp_cells)
                if ent.dp_ids is not None and cache.dp_edge is not None:
                    self._nic_dp_fast(
                        cache, ent, bw[ent.off_dp:ent.off_hop]
                    )
                if ent.hop_ids is not None and cache.hop_bw is not None:
                    cache.hop_bw.reshape(-1)[ent.hop_ids] = bw[ent.off_hop:]
                    hop_cols.update(ent.hop_cols)

        link_bw = state.link_bw
        for s, d2, e in tp_e:
            cache.tp_edge[s, d2, e] = link_bw(
                int(grid[s, d2, e]), int(grid[s, d2, (e + 1) % tp])
            )
            tp_cells.add((s, d2))
        for s, f, k2 in dp_e:
            cache.dp_edge[s, f, k2] = link_bw(
                int(grid[s, f, k2]), int(grid[s, (f + 1) % dp, k2])
            )
            dp_rings.add((s, k2))
        for hs, d2 in hop_e:
            cache.hop_bw[hs, d2] = link_bw(
                int(grid[hs, d2, 0]), int(grid[hs + 1, d2, 0])
            )
            hop_cols.add(d2)

        compute = state._compute
        host = state._host
        for s, d2 in cell_dirty:
            row = grid[s, d2]
            cache.cell_speed[s, d2] = (compute[row] * host[row]).min()
        for s, d2 in tp_cells:
            cache.tp_bw[s, d2] = cache.tp_edge[s, d2].min()
        stage_cols: set[int] = set()
        for s, d2 in cell_dirty | tp_cells:
            # Scalar replay of _stage_from through the factored constants.
            t = cache.c_flops / (cache.c_speed * cache.cell_speed[s, d2])
            if cache.tp_bw is not None:
                t += cache.c_tp / cache.tp_bw[s, d2]
            cache.stage[s, d2] = t
            stage_cols.add(d2)
        for d2 in stage_cols:
            cache.stage_max[d2] = max(cache.stage[:, d2].tolist())
        if len(dp_rings) > 2:
            rs = np.fromiter((s for s, _ in dp_rings), np.int64, len(dp_rings))
            rk = np.fromiter((k for _, k in dp_rings), np.int64, len(dp_rings))
            cache.dp_bw[rs, rk] = cache.dp_edge[rs, :, rk].min(axis=1)
            if cache.dp_arg is not None:
                cache.dp_arg[rs, rk] = -1
        else:
            for s, k2 in dp_rings:
                cache.dp_bw[s, k2] = cache.dp_edge[s, :, k2].min()
                if cache.dp_arg is not None:
                    cache.dp_arg[s, k2] = -1
        for d2 in hop_cols:
            # Sequential accumulation: the full pass's axis-0 sum reduces
            # row by row (never pairwise along the outer axis), and a 1-D
            # .sum() would switch to pairwise at >= 9 hops and drift a ulp.
            acc = 0.0
            for bw in cache.hop_bw[:, d2].tolist():
                acc += cache.pp_vol / bw
            cache.hop2[d2] = 2.0 * acc

    def _nic_dp_fast(self, cache: _Cells, ent, new: np.ndarray) -> None:
        """Scatter a node's re-measured DP-ring edges and refresh the
        touched rings' bottlenecks through the per-ring argmin cache.

        Correctness of the O(touched) rules (untouched edges are unchanged,
        so every untouched edge >= the ring's cached minimum ``cur``):

        * candidate ``cand`` = min over the touched edges' *new* values.
          If ``cand <= cur`` the ring minimum is exactly ``cand`` (any
          untouched edge >= cur >= cand) — assign value and argmin in O(1).
        * Else (every touched edge rose above ``cur``): if the cached
          bottleneck edge is *untouched*, its value still is ``cur`` and
          nothing beats it — the ring minimum is unchanged, no work.
        * Only when the bottleneck itself rose (a restore event) does the
          ring pay a full re-min + argmin. A stored argmin may be any
          position attaining the minimum (ties); the rule above stays valid
          for every such choice.

        The assigned floats are the same doubles a full ``.min(axis=1)``
        would produce, so bit-exactness against the reference oracles is
        preserved.
        """
        cache.dp_edge.reshape(-1)[ent.dp_ids] = new
        rs, rk = ent.ring_s, ent.ring_k
        if cache.dp_arg is None:
            cache.dp_arg = np.full(cache.dp_bw.shape, -1, dtype=np.int64)
        if not ent.uniform:
            # Irregular edges-per-ring grouping (nonstandard topology):
            # fall back to full re-min over the touched rings.
            sub = cache.dp_edge[rs, :, rk]
            cache.dp_bw[rs, rk] = sub.min(axis=1)
            cache.dp_arg[rs, rk] = sub.argmin(axis=1)
            return
        m = new[ent.dp_order].reshape(rs.size, ent.dp_width)
        j = m.argmin(axis=1)
        cand = m[ent.dp_rows, j]
        cur = cache.dp_bw[rs, rk]
        take = cand <= cur
        if take.all():
            # Degrade event: every touched ring's candidate wins — O(1)
            # per ring, no gathers (the common fast-path in churn).
            cache.dp_bw[rs, rk] = cand
            cache.dp_arg[rs, rk] = ent.dp_dpos2[ent.dp_rows, j]
            return
        curarg = cache.dp_arg[rs, rk]
        redo = ~take & (
            (curarg < 0) | (ent.dp_dpos2 == curarg[:, None]).any(axis=1)
        )
        if not take.any():
            # Restore event: only rings whose cached bottleneck edge rose
            # (or whose argmin is unknown) pay a full re-min + argmin.
            if redo.all():
                sub = cache.dp_edge[rs, :, rk]
                cache.dp_bw[rs, rk] = sub.min(axis=1)
                cache.dp_arg[rs, rk] = sub.argmin(axis=1)
            elif redo.any():
                sub = cache.dp_edge[rs[redo], :, rk[redo]]
                cache.dp_bw[rs[redo], rk[redo]] = sub.min(axis=1)
                cache.dp_arg[rs[redo], rk[redo]] = sub.argmin(axis=1)
            return
        cand_d = ent.dp_dpos2[ent.dp_rows, j]
        cache.dp_bw[rs[take], rk[take]] = cand[take]
        cache.dp_arg[rs[take], rk[take]] = cand_d[take]
        if redo.any():
            sub = cache.dp_edge[rs[redo], :, rk[redo]]
            cache.dp_bw[rs[redo], rk[redo]] = sub.min(axis=1)
            cache.dp_arg[rs[redo], rk[redo]] = sub.argmin(axis=1)

    def _cells_update_positions(
        self, cache: _Cells, lay: _Layout, pos: np.ndarray
    ) -> None:
        """Re-reduce only what the logical positions ``pos`` touch: their
        incident ring edges, then the containing cells' speed minima, stage
        times, ring minima and activation hops.

        Each update applies the exact operation chain of the full pass to
        the touched slices (same gathers, same reduction order over the
        same cached values), so the arrays stay bit-identical to a
        from-scratch rebuild.
        """
        state = self.state
        pp, dp, tp = self.job.pp, self.job.dp, self.job.tp
        grid = lay.grid
        if pos.size <= 3:
            # The batched path below costs ~30 small array ops regardless of
            # size; for the 1-2 positions a device or link event dirties,
            # per-position scalar updates are cheaper (re-reducing a shared
            # cell twice just re-stores the same bits). Node-scoped dirt
            # (CPU/NIC: a whole node's devices) stays on the batched path.
            for p in pos:
                self._cell_update_one(cache, lay, int(p))
            return
        s = pos // (dp * tp)
        rem = pos % (dp * tp)
        dd = rem // tp
        kk = rem % tp
        cells = np.unique(s * dp + dd)
        cs, cd = cells // dp, cells % dp
        rows = grid[cs, cd]  # (m, tp)
        cache.cell_speed[cs, cd] = (
            state._compute[rows] * state._host[rows]
        ).min(axis=1)
        # One fused link_bw_many sweep over every dirty ring/hop edge, then
        # scatter the results back per edge class. A position's incident
        # edges: k-1 -> k and k -> k+1 in its TP cell, d-1 -> d and d -> d+1
        # in its DP ring (indices mod size; duplicates re-store equal bits).
        seg_a: list[np.ndarray] = []
        seg_b: list[np.ndarray] = []
        tp_idx = dp_idx = hop_idx = None
        if cache.tp_edge is not None:
            es = np.concatenate([s, s])
            ed = np.concatenate([dd, dd])
            ek = np.concatenate([(kk - 1) % tp, kk])
            tp_idx = (es, ed, ek)
            seg_a.append(grid[es, ed, ek])
            seg_b.append(grid[es, ed, (ek + 1) % tp])
        if cache.dp_edge is not None:
            es = np.concatenate([s, s])
            ek = np.concatenate([kk, kk])
            ed = np.concatenate([(dd - 1) % dp, dd])
            dp_idx = (es, ed, ek)
            seg_a.append(grid[es, ed, ek])
            seg_b.append(grid[es, (ed + 1) % dp, ek])
        if cache.hop_bw is not None:
            hs, hd = s[kk == 0], dd[kk == 0]
            up, down = hs > 0, hs < pp - 1
            hops = np.unique(np.concatenate(
                [(hs[up] - 1) * dp + hd[up], hs[down] * dp + hd[down]]
            ))
            if hops.size:
                hop_idx = (hops // dp, hops % dp)
                seg_a.append(grid[hop_idx[0], hop_idx[1], 0])
                seg_b.append(grid[hop_idx[0] + 1, hop_idx[1], 0])
        if seg_a:
            bw = state.link_bw_many(
                np.concatenate(seg_a), np.concatenate(seg_b)
            )
            off = 0
            if tp_idx is not None:
                m = tp_idx[0].size
                cache.tp_edge[tp_idx] = bw[off:off + m]
                off += m
                cache.tp_bw[cs, cd] = cache.tp_edge[cs, cd].min(axis=1)
            if dp_idx is not None:
                m = dp_idx[0].size
                cache.dp_edge[dp_idx] = bw[off:off + m]
                off += m
                rings = np.unique(s * tp + kk)
                rs, rk = rings // tp, rings % tp
                cache.dp_bw[rs, rk] = cache.dp_edge[rs, :, rk].min(axis=1)
                if cache.dp_arg is not None:
                    cache.dp_arg[rs, rk] = -1
            if hop_idx is not None:
                cache.hop_bw[hop_idx] = bw[off:]
        cache.stage[cs, cd] = self._stage_from(
            cache.cell_speed[cs, cd],
            None if cache.tp_bw is None else cache.tp_bw[cs, cd],
        )
        cache.stage_max[cd] = cache.stage[:, cd].max(axis=0)
        if cache.hop_bw is not None:
            cache.hop2[cd] = 2.0 * (
                cache.pp_vol / cache.hop_bw[:, cd]
            ).sum(axis=0)

    def _cell_update_one(self, cache: _Cells, lay: _Layout, p: int) -> None:
        """Scalar fast path of :meth:`_cells_update_positions` for the
        single-position dirt a typical fail-slow event produces — plain
        index arithmetic instead of array batching, same operation chains
        (``link_bw`` and ``link_bw_many`` are kept in bit-identical
        lockstep, see :mod:`repro.cluster.spec`)."""
        state = self.state
        pp, dp, tp = self.job.pp, self.job.dp, self.job.tp
        grid = lay.grid
        s, rem = divmod(p, dp * tp)
        d2, k2 = divmod(rem, tp)
        row = grid[s, d2]  # (tp,) view
        cache.cell_speed[s, d2] = (
            state._compute[row] * state._host[row]
        ).min()
        if cache.tp_edge is not None:
            e0 = (k2 - 1) % tp
            for e in (e0, k2) if e0 != k2 else (k2,):
                cache.tp_edge[s, d2, e] = state.link_bw(
                    int(row[e]), int(row[(e + 1) % tp])
                )
            cache.tp_bw[s, d2] = cache.tp_edge[s, d2].min()
        cache.stage[s, d2] = self._stage_from(
            cache.cell_speed[s, d2],
            None if cache.tp_bw is None else cache.tp_bw[s, d2],
        )
        if cache.dp_edge is not None:
            f0 = (d2 - 1) % dp
            for f in (f0, d2) if f0 != d2 else (d2,):
                cache.dp_edge[s, f, k2] = state.link_bw(
                    int(grid[s, f, k2]), int(grid[s, (f + 1) % dp, k2])
                )
            cache.dp_bw[s, k2] = cache.dp_edge[s, :, k2].min()
            if cache.dp_arg is not None:
                cache.dp_arg[s, k2] = -1
        if cache.hop_bw is not None and k2 == 0:
            for hs in (s - 1, s):
                if 0 <= hs < pp - 1:
                    cache.hop_bw[hs, d2] = state.link_bw(
                        int(grid[hs, d2, 0]), int(grid[hs + 1, d2, 0])
                    )
        cache.stage_max[d2] = cache.stage[:, d2].max()
        if cache.hop_bw is not None:
            # Sequential like the full pass's axis-0 sum (see _apply_dirty).
            acc = 0.0
            for bw in cache.hop_bw[:, d2].tolist():
                acc += cache.pp_vol / bw
            cache.hop2[d2] = 2.0 * acc

    def _cells_if_current(self) -> _Cells | None:
        """The cell cache, brought up to date with the state's mutation log
        — or None when it must be rebuilt (placement/state/job/cluster
        reassigned, incremental mode off, or the reader's cursor fell off
        the retained log). Single source of the freshness rule for both
        :meth:`_cells` and :meth:`remap_groups`."""
        d = self.__dict__
        cache = d.get("_cells_cache")
        if (
            cache is None
            or not self.incremental
            or d.get("_cells_place_ver") != d["_place_ver"]
            or d.get("_cells_state_uid") != self.state.uid
        ):
            return None
        ds = self.state.dirty_since(d["_cells_cursor"])
        d["_cells_cursor"] = self.state.cursor()
        if ds.full:
            return None
        if ds:
            self._apply_dirty(cache, self._layout(), ds)
        return cache

    def _cells(self) -> _Cells:
        """The cached per-cell reductions, refreshed event-scoped.

        Consumes the state's mutation log from this simulator's cursor:
        an empty dirty set returns the cache untouched, a typed dirty set
        re-reduces only the affected cells, and a full/overflowed one (or
        any placement/job/cluster/state reassignment) rebuilds everything —
        the pre-refactor behavior.
        """
        cache = self._cells_if_current()
        if cache is not None:
            return cache
        d = self.__dict__
        lay = self._layout()
        cache = self._cells_rebuild(lay)
        d["_cells_cache"] = cache
        d["_cells_place_ver"] = d["_place_ver"]
        d["_cells_state_uid"] = self.state.uid
        d["_cells_cursor"] = self.state.cursor()
        return cache

    def _stage_times(self) -> np.ndarray:
        """Per-(stage, dp_rank) time of one micro-batch, shape (pp, dp)."""
        return self._cells().stage

    def _dp_ring_times(self, volume: float, c: _Cells | None = None) -> np.ndarray:
        """All-reduce time of every (stage, tp_rank) DP ring, shape (pp, tp)."""
        bw = (c or self._cells()).dp_bw
        return 2.0 * (self.job.dp - 1) / self.job.dp * volume / bw

    def _alloc_off(self) -> np.ndarray:
        """``allocation + pp - 1`` as an int64 array, memoized until the
        allocation list is reassigned (integer arithmetic, order-exact)."""
        d = self.__dict__
        if d.get("_alloc_arr") is None:
            d["_alloc_arr"] = (
                np.asarray(self.allocation, dtype=np.int64) + self.job.pp - 1
            )
        return d["_alloc_arr"]

    def _reduction_backend(self):
        """The resolved :data:`REDUCTION_BACKENDS` instance, or None for
        the inline vectorized fast path (the hot-path default — no
        per-call indirection). Resolved lazily, re-resolved whenever the
        ``reduction`` field is reassigned."""
        d = self.__dict__
        obj = d.get("_red_obj", False)
        if obj is False:
            obj = resolve_reduction_backend(self.reduction)
            d["_red_obj"] = obj
        return obj

    def iteration_time(self) -> float:
        key = (self.__dict__["_cfg_ver"], self.state.version)
        d = self.__dict__
        if d.get("_it_key") == key:
            return d["_it_val"]
        rb = self._reduction_backend()
        t = (
            self._vec_iteration_time() if rb is None
            else float(rb.iteration_time(self))
        )
        d["_it_key"] = key
        d["_it_val"] = t
        return t

    def _vec_iteration_time(self) -> float:
        """The vectorized (numpy) reduction tree over the cell cache."""
        c = self._cells()
        pipe = self._alloc_off() * c.stage_max
        if c.hop_bw is not None:
            pipe += c.hop2
        t = float(pipe.max())
        if self.job.dp > 1:
            # max over C / bw == C / bw.min(): the winning element is the
            # same division of the same two doubles either way.
            t += float(c.c_dp / c.dp_bw.min())
        return t

    def per_microbatch_times(self) -> list[float]:
        """Per-DP-group per-micro-batch processing time (S2 solver input)."""
        rb = self._reduction_backend()
        if rb is not None:
            return rb.per_microbatch_times(self)
        return [float(v) for v in self._cells().stage_max]

    # -------------------------------------- per-collective decomposition
    def collective_breakdown(self) -> CollectiveBreakdown:
        """The current iteration's critical-path time split into compute /
        TP-allreduce / PP-p2p / DP-allreduce, with the bottleneck
        collective, profiling group and ring edge named (local ranks —
        the same ids the detector's component validation uses). Reads the
        cached per-cell reductions, so after an ``iteration_time()`` it
        costs O(cells); the control plane attaches one to every onset
        Diagnosis. See docs/observability.md for the contract.
        """
        return decompose(self)

    def timing_decomposition(self) -> dict[str, list]:
        """Every cell's time split as nested lists — the per-cell
        companion of :meth:`collective_breakdown` (TP/DP entries match
        :meth:`profile_groups` bit for bit)."""
        return timing_decomposition(self)

    def healthy_iteration_time(self) -> float:
        """Iteration time with all components healthy and even allocation.

        Depends only on the (immutable) job and cluster specs, so it is
        computed once per simulator.
        """
        d = self.__dict__
        if d.get("_healthy_cache") is None:
            saved_state, saved_alloc = self.state, self.allocation
            saved_place = self.placement
            self.state = ClusterState(self.cluster)
            base, extra = divmod(self.job.micro_batches, self.job.dp)
            self.allocation = [
                base + (1 if i < extra else 0) for i in range(self.job.dp)
            ]
            self.placement = list(range(self.job.n_devices))
            t = self.iteration_time()
            self.state, self.allocation, self.placement = (
                saved_state, saved_alloc, saved_place,
            )
            d["_healthy_cache"] = t
        return d["_healthy_cache"]

    # ----------------------------------------- reference implementations
    # The seed's nested-loop model, kept verbatim as the equivalence oracle
    # for the vectorized fast path (tests pin both to 1e-9; in practice the
    # operation chains are identical and results match bit for bit).
    def _cell_speed(self, stage: int, dp_rank: int) -> float:
        """TP-synchronized cell runs at its slowest member's speed."""
        return min(self.state.effective_speed(d) for d in self._cell_devices(stage, dp_rank))

    def _ring_time(self, devices: list[int], volume: float) -> float:
        """Ring all-reduce time: 2(n-1)/n x volume over the slowest edge."""
        n = len(devices)
        if n <= 1 or volume <= 0:
            return 0.0
        bw = min(
            self.state.link_bw(devices[i], devices[(i + 1) % n]) for i in range(n)
        )
        return 2.0 * (n - 1) / n * volume / bw

    def _stage_time_per_microbatch(self, stage: int, dp_rank: int) -> float:
        m = self.job.model
        compute = m.flops_per_microbatch() / self.job.pp / (
            self.job.tp * self.cluster.gpu_flops * self._cell_speed(stage, dp_rank)
        )
        tp_vol = m.comm_tp_bytes(self.job.tp, self.job.pp, 1)
        tp_time = self._ring_time(self._cell_devices(stage, dp_rank), tp_vol)
        return compute + tp_time

    def _pipeline_time(self, dp_rank: int) -> float:
        """1F1B: (m + P - 1) x slowest stage + activation hops."""
        m_d = self.allocation[dp_rank]
        stage_t = max(
            self._stage_time_per_microbatch(s, dp_rank) for s in range(self.job.pp)
        )
        pp_vol = self.job.model.comm_pp_bytes(1)
        hop = 0.0
        for s in range(self.job.pp - 1):
            a = self.device_at(s, dp_rank, 0)
            b = self.device_at(s + 1, dp_rank, 0)
            hop += pp_vol / self.state.link_bw(a, b)
        return (m_d + self.job.pp - 1) * stage_t + 2.0 * hop

    def _dp_allreduce_time(self) -> float:
        if self.job.dp <= 1:
            return 0.0
        vol = self.job.model.comm_dp_bytes(self.job.tp, self.job.pp)
        worst = 0.0
        for s in range(self.job.pp):
            for k in range(self.job.tp):
                ring = [self.device_at(s, d, k) for d in range(self.job.dp)]
                worst = max(worst, self._ring_time(ring, vol))
        return worst

    def iteration_time_reference(self) -> float:
        """Original loop implementation (equivalence oracle; no memo)."""
        pipe = max(self._pipeline_time(d) for d in range(self.job.dp))
        return pipe + self._dp_allreduce_time()

    def per_microbatch_times_reference(self) -> list[float]:
        return [
            max(
                self._stage_time_per_microbatch(s, d) for s in range(self.job.pp)
            )
            for d in range(self.job.dp)
        ]

    def profile_groups_reference(self) -> dict[str, float]:
        out: dict[str, float] = {}
        m = self.job.model
        tp_vol = m.comm_tp_bytes(self.job.tp, self.job.pp, 1)
        dp_vol = m.comm_dp_bytes(self.job.tp, self.job.pp)
        for s in range(self.job.pp):
            for d in range(self.job.dp):
                if self.job.tp > 1:
                    cell = self._cell_devices(s, d)
                    out[f"tp:s{s}d{d}"] = self._ring_time(cell, tp_vol)
            for k in range(self.job.tp):
                if self.job.dp > 1:
                    ring = [self.device_at(s, d, k) for d in range(self.job.dp)]
                    out[f"dp:s{s}t{k}"] = self._ring_time(ring, dp_vol)
        return out

    # -------------------------------------------------- mitigation hooks
    def set_allocation(self, counts: list[int]) -> None:
        if len(counts) != self.job.dp or sum(counts) != self.job.micro_batches:
            raise ValueError("bad allocation")
        self.allocation = list(counts)

    def apply_placement(self, perm: list[int]) -> None:
        """Compose a logical->physical permutation onto current placement."""
        if sorted(perm) != list(range(self.job.n_devices)):
            raise ValueError("not a permutation")
        self.placement = [self.placement[p] for p in perm]

    def remap_groups(self, placement: list[int]) -> None:
        """Re-shape communication groups to an explicit device placement.

        ``placement`` lists the physical device for every logical position
        (HybridTopology stage-major order) and must permute the job's
        *current* device set — this is the placement-aware mitigation hook
        (:mod:`repro.core.placement`): swapping ranks across DP groups
        concentrates a slow host's members into few groups so S2/S3 have
        skew to exploit.

        Unlike reassigning ``placement`` directly, the cached
        :class:`_Layout` is refreshed *incrementally* (index tensors
        rebuilt in place, group-key strings reused) instead of being built
        from scratch on the next evaluation — and the per-cell reduction
        cache stays live: only cells whose membership actually changed (plus
        any pending state dirt) are re-reduced, so a measure-before-commit
        candidate sweep (S2P/S3P) pays per remapped cell, not per cluster.
        """
        new_arr = np.asarray(placement, dtype=np.int64)
        old_arr = np.asarray(self.placement, dtype=np.int64)
        if new_arr.shape != old_arr.shape:
            raise ValueError("remap must permute the job's current devices")
        changed = np.flatnonzero(new_arr != old_arr)
        # Permutation check on the changed subset only (unchanged positions
        # cancel out of the multiset comparison) — O(moved log moved), not
        # O(devices log devices) per candidate evaluation.
        if not np.array_equal(
            np.sort(new_arr[changed]), np.sort(old_arr[changed])
        ):
            raise ValueError("remap must permute the job's current devices")
        new = new_arr.tolist()
        d = self.__dict__
        lay = d.get("_layout_cache")
        fresh = lay is not None and d.get("_layout_ver") == d.get("_place_ver")
        # Sync any unapplied state dirt against the *old* grid first (the
        # cache must equal a rebuild for the old placement before the
        # membership delta is applied on top).
        cache = self._cells_if_current()
        self.placement = new  # bumps placement/config versions
        if fresh:
            lay.update(new, self.job)
            d["_layout_ver"] = d["_place_ver"]
        if cache is not None:
            # Re-reduce only the positions whose device changed.
            if changed.size:
                self._cells_update_positions(cache, self._layout(), changed)
            d["_cells_place_ver"] = d["_place_ver"]

    def restart(self) -> None:
        """S4: checkpoint-and-restart onto healthy devices (modeled as a
        placement reset + the caller charging the restart overhead)."""
        self.placement = list(range(self.job.n_devices))
        base, extra = divmod(self.job.micro_batches, self.job.dp)
        self.allocation = [base + (1 if i < extra else 0) for i in range(self.job.dp)]

    # -------------------------------------------- hang / stall semantics
    #: a job is *stalled* (hung, not merely degraded) when its iteration
    #: runs this many times slower than healthy — far past any composition
    #: of severity-tier throttles, but far below the ~10⁶× a HANG_EPS
    #: injection produces, so throttles never trip it and hangs always do
    stall_factor = 500.0

    def stalled(self) -> bool:
        """True when the job makes effectively no progress (a hang).

        A stalled job emits no iteration samples: the monitor's current
        iteration never completes, which is exactly the stream-goes-silent
        shape the control plane's watchdog exists to catch.
        """
        return (
            self.iteration_time()
            >= self.stall_factor * self.healthy_iteration_time()
        )

    # ------------------------------------------------ snapshot / restore
    def snapshot(self) -> dict:
        """Capture placement, micro-batch allocation, and hardware state.

        The fault-tolerant executor snapshots before every mitigation
        attempt and calls :meth:`restore` when the attempt fails mid-flight,
        guaranteeing the simulator is bit-identical to its pre-action state.
        """
        st = self.state
        return {
            "placement": list(self.placement),
            "allocation": list(self.allocation),
            "compute": st._compute.copy(),
            "host": st._host.copy(),
            "link_mult": dict(st.link_mult),
            "nic_mult": dict(st.nic_mult),
        }

    def restore(self, snap: dict) -> None:
        """Roll back to a :meth:`snapshot`, through the logged surfaces.

        Every write goes through the same mutation-logged setters the
        injector uses (and diffs against the current value first), so the
        dirty-set/memoization contracts hold and an already-identical
        component contributes no spurious dirt.
        """
        if list(self.placement) != snap["placement"]:
            self.placement = list(snap["placement"])
        if list(self.allocation) != snap["allocation"]:
            self.allocation = list(snap["allocation"])
        st = self.state
        comp, host = snap["compute"], snap["host"]
        for i in np.flatnonzero(st._compute != comp):
            st.devices[int(i)].compute_speed = float(comp[i])
        for i in np.flatnonzero(st._host != host):
            st.devices[int(i)].host_speed = float(host[i])
        for vdict, saved in (
            (st.link_mult, snap["link_mult"]),
            (st.nic_mult, snap["nic_mult"]),
        ):
            for k in list(vdict):
                if k not in saved:
                    del vdict[k]
            for k, v in saved.items():
                vdict[k] = v  # no-ops (and stays clean) when already equal

    # ---------------------------------------------- monitor event stream
    ITER_PATTERN = (CommOp.REDUCE_SCATTER, CommOp.ALL_GATHER, CommOp.ALL_REDUCE)

    def emit_events(self, t_start: float, iter_time: float, rank: int = 0) -> list[CommEvent]:
        """CommEvents one real iteration would leave in the Monitor log."""
        k = len(self.ITER_PATTERN)
        return [
            CommEvent(op=op, timestamp=t_start + iter_time * (i / k), rank=rank)
            for i, op in enumerate(self.ITER_PATTERN)
        ]

    # --------------------------------------- dirty-cursor adapter surface
    def state_cursor(self) -> tuple[int, int]:
        """Opaque cursor over the hardware mutation log: (state identity,
        log position — see :meth:`repro.cluster.spec.ClusterState.cursor`).
        Control-plane readers store this and poll :meth:`dirty_since` to
        learn which hardware components moved — each registered job keeps
        its own cursor, so one job's faults cost co-registered jobs
        nothing. The identity token guards against ``sim.state`` being
        reassigned wholesale (probe swaps, restarts onto a fresh state):
        a cursor from the old state reads as everything-dirty, never as
        clean."""
        return (self.state.uid, self.state.cursor())

    def dirty_since(self, cursor: tuple[int, int]):
        """Typed :class:`~repro.cluster.spec.DirtySet` of components mutated
        since ``cursor`` (device ranks, link pairs, NIC nodes — all in this
        job's local coordinates). Full-dirty when the cursor belongs to a
        previous state object."""
        uid, pos = cursor
        if uid != self.state.uid:
            return DirtySet(full=True)
        return self.state.dirty_since(pos)

    # ------------------------------------- ClusterInterface (FALCON R1)
    def profile_groups(self) -> dict[str, float]:
        """Per-communication-group transfer time (profiling phase)."""
        rb = self._reduction_backend()
        if rb is not None:
            return rb.profile_groups(self)
        return self._vec_profile_groups()

    def _vec_profile_groups(self) -> dict[str, float]:
        lay = self._layout()
        c = self._cells()
        out: dict[str, float] = {}
        m = self.job.model
        if c.tp_bw is not None:
            tp_vol = m.comm_tp_bytes(self.job.tp, self.job.pp, 1)
            times = 2.0 * (self.job.tp - 1) / self.job.tp * tp_vol / c.tp_bw
            out.update(zip(lay.tp_keys, times.reshape(-1).tolist(), strict=True))
        if c.dp_bw is not None:
            dp_vol = m.comm_dp_bytes(self.job.tp, self.job.pp)
            times = self._dp_ring_times(dp_vol)
            out.update(zip(lay.dp_keys, times.reshape(-1).tolist(), strict=True))
        return out

    def group_ranks(self, group: str) -> list[int]:
        kind, coords = group.split(":")
        if kind == "tp":
            s, d = coords[1:].split("d")
            return self._cell_devices(int(s), int(d))
        s, k = coords[1:].split("t")
        return [self.device_at(int(s), d, int(k)) for d in range(self.job.dp)]

    def benchmark_compute(self, ranks: list[int]) -> dict[int, float]:
        """GEMM validation: time inversely proportional to device speed.

        CPU contention does *not* show up here (paper case study 1: the GPU
        matmul test found no degradation) — only compute_speed matters.
        """
        return {
            r: self.cluster.gemm_ref_time / self.state.devices[r].compute_speed
            for r in ranks
        }

    def measure_link(self, pair: tuple[int, int]) -> float:
        a, b = pair
        return self.cluster.p2p_payload / self.state.link_bw(a, b)

    def measure_links(self, pairs: np.ndarray) -> np.ndarray:
        """Batched :meth:`measure_link` over an (k, 2) pair array.

        Rides on :meth:`ClusterState.link_bw_many`, so one call validates
        every ring pass of every suspicious group — the detector's
        vectorized validation sweep."""
        pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
        return self.cluster.p2p_payload / self.state.link_bw_many(
            pairs[:, 0], pairs[:, 1]
        )

    def healthy_link_time(self, pair: tuple[int, int]) -> float:
        """Expected healthy time for this link class (fabric is known)."""
        a, b = pair
        return self.cluster.p2p_payload / self.cluster.base_link_bw(a, b)

    def healthy_link_times(self, pairs: np.ndarray) -> np.ndarray:
        """Batched :meth:`healthy_link_time` over an (k, 2) pair array."""
        pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
        return self.cluster.p2p_payload / self.cluster.base_link_bw_many(
            pairs[:, 0], pairs[:, 1]
        )

    def healthy_compute_time(self) -> float:
        """Reference GEMM time on a healthy device."""
        return self.cluster.gemm_ref_time

    # -------------------------------------- node-scoped validation surface
    def node_of_rank(self, rank: int) -> int:
        """Node hosting a device rank (NIC/host clustering in validation)."""
        return self.cluster.node_of(rank)

    def benchmark_host(self, nodes: list[int]) -> dict[int, float]:
        """Host-side benchmark per node: CPU contention slows the whole
        node's host path, which the GPU GEMM sweep cannot see."""
        per = self.cluster.gpus_per_node
        out: dict[int, float] = {}
        for n in nodes:
            speed = min(
                self.state.devices[d].host_speed
                for d in range(n * per, (n + 1) * per)
            )
            out[n] = self.cluster.host_ref_time / speed
        return out

    def healthy_host_time(self) -> float:
        """Reference host benchmark time on a healthy node."""
        return self.cluster.host_ref_time

    def measure_nic(self, node: int) -> float:
        """P2P time through one node's NIC port (inter-node path)."""
        return self.cluster.p2p_payload / (
            self.cluster.inter_node_bw * self.state.nic_mult.get(node, 1.0)
        )

    def healthy_nic_time(self) -> float:
        """Expected healthy inter-node P2P time (NIC at full rate)."""
        return self.cluster.p2p_payload / self.cluster.inter_node_bw


# ---------------------------------------------------------------------------
# Reduction backends
# ---------------------------------------------------------------------------
@runtime_checkable
class ReductionBackend(Protocol):
    """How a :class:`TrainingSimulator` turns its measured per-cell arrays
    into iteration-level answers.

    Implementations own everything downstream of measurement — the ring
    minima, stage maxima, hop sums and critical-path reductions — and are
    interchangeable behind ``TrainingSimulator.reduction``. ``tolerance``
    is the documented relative error versus the ``reference`` loop oracle
    (0.0 = bit-exact); the equivalence suite enumerates
    :data:`REDUCTION_BACKENDS` and asserts each backend within its own
    tolerance. See docs/kernels.md for the contract and how to register a
    new backend.
    """

    name: str
    tolerance: float

    def iteration_time(self, sim: TrainingSimulator) -> float: ...

    def per_microbatch_times(self, sim: TrainingSimulator) -> list[float]: ...

    def profile_groups(self, sim: TrainingSimulator) -> dict[str, float]: ...


class ReferenceReduction:
    """The seed's nested-loop oracle as a backend (slow, bit-exact)."""

    name = "reference"
    tolerance = 0.0

    def iteration_time(self, sim: TrainingSimulator) -> float:
        return sim.iteration_time_reference()

    def per_microbatch_times(self, sim: TrainingSimulator) -> list[float]:
        return sim.per_microbatch_times_reference()

    def profile_groups(self, sim: TrainingSimulator) -> dict[str, float]:
        return sim.profile_groups_reference()


class VectorizedReduction:
    """The numpy fast path as an explicit backend object.

    ``sim.reduction = "vectorized"`` (and "auto" on a CPU-only jax) skips
    this object entirely and runs the same code inline — this class exists
    so the equivalence suite can drive every registry entry uniformly.
    """

    name = "vectorized"
    tolerance = 0.0

    def iteration_time(self, sim: TrainingSimulator) -> float:
        return sim._vec_iteration_time()

    def per_microbatch_times(self, sim: TrainingSimulator) -> list[float]:
        return [float(v) for v in sim._cells().stage_max]

    def profile_groups(self, sim: TrainingSimulator) -> dict[str, float]:
        return sim._vec_profile_groups()


class PallasReduction:
    """Fused-kernel backend: one :mod:`repro.kernels.cell_reduce` launch
    per evaluation (memoized on the simulator's config/state versions).

    Measurement (and its event-scoped incremental maintenance) stays on
    the numpy side; the kernel fuses every reduction after it. Degenerate
    topologies (any of tp/dp/pp == 1) fall back to the vectorized path.
    ``tolerance`` reflects float32 kernel arithmetic against the float64
    oracle (see docs/kernels.md).
    """

    name = "pallas"
    tolerance = 1e-4

    def __init__(self, interpret: bool | None = None) -> None:
        self.interpret = interpret

    def _outs(self, sim: TrainingSimulator):
        d = sim.__dict__
        key = (d["_cfg_ver"], sim.state.version)
        if d.get("_red_key") == key:
            return d["_red_val"]
        c = sim._cells()
        if c.tp_edge is None or c.dp_edge is None or c.hop_bw is None:
            out = None
        else:
            from repro.kernels.cell_reduce import cell_reduce

            t, stage_max, tp_bw, dp_bw = cell_reduce(
                c.cell_speed, c.tp_edge, c.dp_edge, c.hop_bw,
                sim._alloc_off(), c.c_flops, c.c_speed, c.c_tp,
                c.pp_vol, c.c_dp, interpret=self.interpret,
            )
            out = (
                float(t[0, 0]),
                [float(v) for v in np.asarray(stage_max[0])],
                np.asarray(tp_bw, dtype=np.float64),
                np.asarray(dp_bw, dtype=np.float64),
            )
        d["_red_key"] = key
        d["_red_val"] = out
        return out

    def iteration_time(self, sim: TrainingSimulator) -> float:
        out = self._outs(sim)
        return sim._vec_iteration_time() if out is None else out[0]

    def per_microbatch_times(self, sim: TrainingSimulator) -> list[float]:
        out = self._outs(sim)
        if out is None:
            return [float(v) for v in sim._cells().stage_max]
        return list(out[1])

    def profile_groups(self, sim: TrainingSimulator) -> dict[str, float]:
        out = self._outs(sim)
        if out is None:
            return sim._vec_profile_groups()
        _, _, tp_bw, dp_bw = out
        lay = sim._layout()
        m = sim.job.model
        job = sim.job
        res: dict[str, float] = {}
        tp_vol = m.comm_tp_bytes(job.tp, job.pp, 1)
        times = 2.0 * (job.tp - 1) / job.tp * tp_vol / tp_bw
        res.update(zip(lay.tp_keys, times.reshape(-1).tolist(), strict=True))
        dp_vol = m.comm_dp_bytes(job.tp, job.pp)
        times = 2.0 * (job.dp - 1) / job.dp * dp_vol / dp_bw
        res.update(zip(lay.dp_keys, times.reshape(-1).tolist(), strict=True))
        return res


#: registry the equivalence tests enumerate; "numpy" mirrors the screening
#: registry's alias for the default non-kernel path
REDUCTION_BACKENDS: dict[str, type] = {
    "reference": ReferenceReduction,
    "vectorized": VectorizedReduction,
    "numpy": VectorizedReduction,
    "pallas": PallasReduction,
}


def _pallas_compiled() -> bool:
    """True when jax is loaded *and* targets a compiled (non-CPU) backend.

    Deliberately checks ``sys.modules`` instead of importing jax: resolving
    the default backend must not drag the jax runtime into every numpy-only
    simulator process.
    """
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        return jax.default_backend() != "cpu"
    except Exception:  # pragma: no cover - uninitialized backends
        return False


def select_reduction_backend(name: str | None = None):
    """Instantiate a reduction backend by registry name; None/"auto" picks
    ``pallas`` on a compiled jax backend and ``vectorized`` otherwise."""
    if name in (None, "auto"):
        name = "pallas" if _pallas_compiled() else "vectorized"
    try:
        cls = REDUCTION_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown reduction backend {name!r}; "
            f"registered: {sorted(REDUCTION_BACKENDS)}"
        ) from None
    return cls()


def resolve_reduction_backend(spec):
    """``TrainingSimulator.reduction`` -> backend instance, or None for the
    inline vectorized fast path ("auto" on CPU-only jax, "vectorized",
    "numpy"). Accepts a registry name or a ready ReductionBackend
    instance."""
    if spec in (None, "auto"):
        return PallasReduction() if _pallas_compiled() else None
    if isinstance(spec, str):
        if spec in ("vectorized", "numpy"):
            return None
        return select_reduction_backend(spec)
    if hasattr(spec, "iteration_time"):
        return spec
    raise TypeError(
        f"reduction must be a registry name or ReductionBackend, got {spec!r}"
    )
