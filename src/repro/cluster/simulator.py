"""Hybrid-parallel training-iteration performance model.

Models one training job under (TP, DP, PP) hybrid parallelism on a
:class:`ClusterState`, with 1F1B pipelining, ring collectives, per-DP-group
micro-batch counts (S2), and a logical->physical placement permutation (S3).
It implements the :class:`repro.core.detector.ClusterInterface` protocol so
FALCON-DETECT runs against it unchanged, and emits the same CommEvent
stream the Monitor shim would log on a real job.

The model intentionally follows the paper's own cost reasoning
(Appendix 9.2): compute time = FLOPs / effective speed; collective time =
ring volume / slowest link; pipeline time = (m + P - 1) x slowest stage.

Fast-path architecture (fleet scale)
------------------------------------
``iteration_time()`` / ``profile_groups()`` / ``per_microbatch_times()``
run on a vectorized core instead of the original nested Python loops:

* A per-placement :class:`_Layout` precomputes the (pp, dp, tp) device-index
  grid, the ring-edge endpoint arrays of every TP cell and DP ring, the PP
  hop endpoints and the profiling-group key strings. It is rebuilt only when
  the placement (or job/cluster) changes.
* Per evaluation, cell speeds and ring times reduce to a handful of gathers
  over :meth:`ClusterState.effective_speeds` / ``link_bw_many`` plus
  ``min``/``max``/``sum`` reductions — O(devices) array work instead of
  O(pp*dp*tp) Python-level calls.
* Results are memoized. The invalidation contract: ``ClusterState.version``
  covers every health mutation (device-speed writes, link/NIC multiplier
  changes, ``reset``), and the simulator bumps an internal config version
  whenever ``placement``/``allocation``/``state`` are reassigned (including
  through ``set_allocation``/``apply_placement``/``restart``). Healthy steps
  between fail-slow events therefore cost O(1); mutate state only through
  those surfaces (lists must be *reassigned*, not edited in place).

The original loop implementations remain as ``*_reference()`` methods; the
fast path matches them bit for bit (equivalence-tested), so benchmark
results are unchanged at lower wall-clock.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.events import CommEvent, CommOp
from repro.core.topology import HybridTopology
from repro.cluster.spec import ClusterSpec, ClusterState, ModelSpec


@dataclass
class JobSpec:
    """One hybrid-parallel training job."""

    model: ModelSpec
    tp: int
    dp: int
    pp: int
    micro_batches: int  # M, per iteration (global batch / micro-batch size)

    @property
    def topology(self) -> HybridTopology:
        return HybridTopology(tp=self.tp, dp=self.dp, pp=self.pp)

    @property
    def n_devices(self) -> int:
        return self.tp * self.dp * self.pp


class _Layout:
    """Placement-derived index tensors, built once per placement.

    ``grid[s, d, k]`` is the physical device at (stage, dp_rank, tp_rank);
    the flattened ring-edge endpoint arrays feed ``link_bw_many`` gathers.
    """

    def __init__(self, placement: list[int], job: JobSpec) -> None:
        self.tp_keys = [
            f"tp:s{s}d{d}" for s in range(job.pp) for d in range(job.dp)
        ]
        self.dp_keys = [
            f"dp:s{s}t{k}" for s in range(job.pp) for k in range(job.tp)
        ]
        self.update(placement, job)

    def update(self, placement: list[int], job: JobSpec) -> None:
        """Refresh the index tensors for a new placement *in place*.

        The incremental rebuild path for :meth:`TrainingSimulator.
        remap_groups`: the group-key strings (the expensive part of a full
        build, and placement-independent) survive; only the device grid and
        the ring/hop endpoint gathers are recomputed — O(devices) array
        work with no Python-level string formatting.
        """
        grid = np.asarray(placement, dtype=np.int64).reshape(
            job.pp, job.dp, job.tp
        )
        self.grid = grid
        self.tp_edges = None
        self.dp_edges = None
        self.hop_edges = None
        if job.tp > 1:
            self.tp_edges = (
                grid.reshape(-1), np.roll(grid, -1, axis=2).reshape(-1)
            )
        if job.dp > 1:
            self.dp_edges = (
                grid.reshape(-1), np.roll(grid, -1, axis=1).reshape(-1)
            )
        if job.pp > 1:
            self.hop_edges = (
                grid[:-1, :, 0].reshape(-1), grid[1:, :, 0].reshape(-1)
            )


@dataclass
class TrainingSimulator:
    """Iteration-time model + FALCON ClusterInterface implementation."""

    cluster: ClusterSpec
    job: JobSpec
    #: logical position p (HybridTopology order) -> physical device perm[p]
    placement: list[int] = field(default_factory=list)
    #: per-DP-group micro-batch counts (S2); default: even split
    allocation: list[int] = field(default_factory=list)
    state: ClusterState = field(init=False)

    def __post_init__(self) -> None:
        if self.job.n_devices > self.cluster.n_devices:
            raise ValueError("job does not fit on the cluster")
        if not self.placement:
            self.placement = list(range(self.job.n_devices))
        if not self.allocation:
            base, extra = divmod(self.job.micro_batches, self.job.dp)
            self.allocation = [
                base + (1 if i < extra else 0) for i in range(self.job.dp)
            ]
        self.state = ClusterState(self.cluster)

    # ------------------------------------------------- memo bookkeeping
    def __setattr__(self, name: str, value) -> None:
        object.__setattr__(self, name, value)
        d = self.__dict__
        if name in ("placement", "job", "cluster"):
            d["_place_ver"] = d.get("_place_ver", 0) + 1
        if name in ("placement", "allocation", "state", "job", "cluster"):
            d["_cfg_ver"] = d.get("_cfg_ver", 0) + 1
        if name in ("job", "cluster"):
            d["_healthy_cache"] = None  # healthy time depends only on these

    def _layout(self) -> _Layout:
        d = self.__dict__
        if d.get("_layout_ver") != d["_place_ver"]:
            d["_layout_cache"] = _Layout(self.placement, self.job)
            d["_layout_ver"] = d["_place_ver"]
        return d["_layout_cache"]

    # ------------------------------------------------------------- layout
    def device_at(self, stage: int, dp_rank: int, tp_rank: int) -> int:
        return self.placement[self.job.topology.position(stage, dp_rank, tp_rank)]

    def _cell_devices(self, stage: int, dp_rank: int) -> list[int]:
        return [self.device_at(stage, dp_rank, k) for k in range(self.job.tp)]

    # --------------------------------------------- vectorized fast path
    def _stage_times(self) -> np.ndarray:
        """Per-(stage, dp_rank) time of one micro-batch, shape (pp, dp)."""
        lay = self._layout()
        m = self.job.model
        cell_speed = self.state.effective_speeds()[lay.grid].min(axis=2)
        compute = (
            m.flops_per_microbatch() / self.job.pp
        ) / (self.job.tp * self.cluster.gpu_flops * cell_speed)
        if lay.tp_edges is not None:
            tp_vol = m.comm_tp_bytes(self.job.tp, self.job.pp, 1)
            bw = self.state.link_bw_many(*lay.tp_edges).reshape(
                self.job.pp, self.job.dp, self.job.tp
            ).min(axis=2)
            compute += 2.0 * (self.job.tp - 1) / self.job.tp * tp_vol / bw
        return compute

    def _dp_ring_times(self, volume: float) -> np.ndarray:
        """All-reduce time of every (stage, tp_rank) DP ring, shape (pp, tp)."""
        lay = self._layout()
        bw = self.state.link_bw_many(*lay.dp_edges).reshape(
            self.job.pp, self.job.dp, self.job.tp
        ).min(axis=1)
        return 2.0 * (self.job.dp - 1) / self.job.dp * volume / bw

    def iteration_time(self) -> float:
        key = (self.__dict__["_cfg_ver"], self.state.version)
        d = self.__dict__
        if d.get("_it_key") == key:
            return d["_it_val"]
        lay = self._layout()
        stage_t = self._stage_times().max(axis=0)  # (dp,)
        if lay.hop_edges is not None:
            pp_vol = self.job.model.comm_pp_bytes(1)
            hop = (
                pp_vol / self.state.link_bw_many(*lay.hop_edges).reshape(
                    self.job.pp - 1, self.job.dp
                )
            ).sum(axis=0)
        else:
            hop = 0.0
        alloc = np.asarray(self.allocation, dtype=np.int64)
        pipe = (alloc + self.job.pp - 1) * stage_t + 2.0 * hop
        t = float(pipe.max())
        if self.job.dp > 1:
            vol = self.job.model.comm_dp_bytes(self.job.tp, self.job.pp)
            t += float(self._dp_ring_times(vol).max())
        d["_it_key"] = key
        d["_it_val"] = t
        return t

    def per_microbatch_times(self) -> list[float]:
        """Per-DP-group per-micro-batch processing time (S2 solver input)."""
        return [float(v) for v in self._stage_times().max(axis=0)]

    def healthy_iteration_time(self) -> float:
        """Iteration time with all components healthy and even allocation.

        Depends only on the (immutable) job and cluster specs, so it is
        computed once per simulator.
        """
        d = self.__dict__
        if d.get("_healthy_cache") is None:
            saved_state, saved_alloc = self.state, self.allocation
            saved_place = self.placement
            self.state = ClusterState(self.cluster)
            base, extra = divmod(self.job.micro_batches, self.job.dp)
            self.allocation = [
                base + (1 if i < extra else 0) for i in range(self.job.dp)
            ]
            self.placement = list(range(self.job.n_devices))
            t = self.iteration_time()
            self.state, self.allocation, self.placement = (
                saved_state, saved_alloc, saved_place,
            )
            d["_healthy_cache"] = t
        return d["_healthy_cache"]

    # ----------------------------------------- reference implementations
    # The seed's nested-loop model, kept verbatim as the equivalence oracle
    # for the vectorized fast path (tests pin both to 1e-9; in practice the
    # operation chains are identical and results match bit for bit).
    def _cell_speed(self, stage: int, dp_rank: int) -> float:
        """TP-synchronized cell runs at its slowest member's speed."""
        return min(self.state.effective_speed(d) for d in self._cell_devices(stage, dp_rank))

    def _ring_time(self, devices: list[int], volume: float) -> float:
        """Ring all-reduce time: 2(n-1)/n x volume over the slowest edge."""
        n = len(devices)
        if n <= 1 or volume <= 0:
            return 0.0
        bw = min(
            self.state.link_bw(devices[i], devices[(i + 1) % n]) for i in range(n)
        )
        return 2.0 * (n - 1) / n * volume / bw

    def _stage_time_per_microbatch(self, stage: int, dp_rank: int) -> float:
        m = self.job.model
        compute = m.flops_per_microbatch() / self.job.pp / (
            self.job.tp * self.cluster.gpu_flops * self._cell_speed(stage, dp_rank)
        )
        tp_vol = m.comm_tp_bytes(self.job.tp, self.job.pp, 1)
        tp_time = self._ring_time(self._cell_devices(stage, dp_rank), tp_vol)
        return compute + tp_time

    def _pipeline_time(self, dp_rank: int) -> float:
        """1F1B: (m + P - 1) x slowest stage + activation hops."""
        m_d = self.allocation[dp_rank]
        stage_t = max(
            self._stage_time_per_microbatch(s, dp_rank) for s in range(self.job.pp)
        )
        pp_vol = self.job.model.comm_pp_bytes(1)
        hop = 0.0
        for s in range(self.job.pp - 1):
            a = self.device_at(s, dp_rank, 0)
            b = self.device_at(s + 1, dp_rank, 0)
            hop += pp_vol / self.state.link_bw(a, b)
        return (m_d + self.job.pp - 1) * stage_t + 2.0 * hop

    def _dp_allreduce_time(self) -> float:
        if self.job.dp <= 1:
            return 0.0
        vol = self.job.model.comm_dp_bytes(self.job.tp, self.job.pp)
        worst = 0.0
        for s in range(self.job.pp):
            for k in range(self.job.tp):
                ring = [self.device_at(s, d, k) for d in range(self.job.dp)]
                worst = max(worst, self._ring_time(ring, vol))
        return worst

    def iteration_time_reference(self) -> float:
        """Original loop implementation (equivalence oracle; no memo)."""
        pipe = max(self._pipeline_time(d) for d in range(self.job.dp))
        return pipe + self._dp_allreduce_time()

    def per_microbatch_times_reference(self) -> list[float]:
        return [
            max(
                self._stage_time_per_microbatch(s, d) for s in range(self.job.pp)
            )
            for d in range(self.job.dp)
        ]

    def profile_groups_reference(self) -> dict[str, float]:
        out: dict[str, float] = {}
        m = self.job.model
        tp_vol = m.comm_tp_bytes(self.job.tp, self.job.pp, 1)
        dp_vol = m.comm_dp_bytes(self.job.tp, self.job.pp)
        for s in range(self.job.pp):
            for d in range(self.job.dp):
                if self.job.tp > 1:
                    cell = self._cell_devices(s, d)
                    out[f"tp:s{s}d{d}"] = self._ring_time(cell, tp_vol)
            for k in range(self.job.tp):
                if self.job.dp > 1:
                    ring = [self.device_at(s, d, k) for d in range(self.job.dp)]
                    out[f"dp:s{s}t{k}"] = self._ring_time(ring, dp_vol)
        return out

    # -------------------------------------------------- mitigation hooks
    def set_allocation(self, counts: list[int]) -> None:
        if len(counts) != self.job.dp or sum(counts) != self.job.micro_batches:
            raise ValueError("bad allocation")
        self.allocation = list(counts)

    def apply_placement(self, perm: list[int]) -> None:
        """Compose a logical->physical permutation onto current placement."""
        if sorted(perm) != list(range(self.job.n_devices)):
            raise ValueError("not a permutation")
        self.placement = [self.placement[p] for p in perm]

    def remap_groups(self, placement: list[int]) -> None:
        """Re-shape communication groups to an explicit device placement.

        ``placement`` lists the physical device for every logical position
        (HybridTopology stage-major order) and must permute the job's
        *current* device set — this is the placement-aware mitigation hook
        (:mod:`repro.core.placement`): swapping ranks across DP groups
        concentrates a slow host's members into few groups so S2/S3 have
        skew to exploit.

        Unlike reassigning ``placement`` directly, the cached
        :class:`_Layout` is refreshed *incrementally* (index tensors
        rebuilt in place, group-key strings reused) instead of being built
        from scratch on the next evaluation.
        """
        new = [int(p) for p in placement]
        if sorted(new) != sorted(self.placement):
            raise ValueError("remap must permute the job's current devices")
        d = self.__dict__
        lay = d.get("_layout_cache")
        fresh = lay is not None and d.get("_layout_ver") == d.get("_place_ver")
        self.placement = new  # bumps placement/config versions
        if fresh:
            lay.update(new, self.job)
            d["_layout_ver"] = d["_place_ver"]

    def restart(self) -> None:
        """S4: checkpoint-and-restart onto healthy devices (modeled as a
        placement reset + the caller charging the restart overhead)."""
        self.placement = list(range(self.job.n_devices))
        base, extra = divmod(self.job.micro_batches, self.job.dp)
        self.allocation = [base + (1 if i < extra else 0) for i in range(self.job.dp)]

    # ---------------------------------------------- monitor event stream
    ITER_PATTERN = (CommOp.REDUCE_SCATTER, CommOp.ALL_GATHER, CommOp.ALL_REDUCE)

    def emit_events(self, t_start: float, iter_time: float, rank: int = 0) -> list[CommEvent]:
        """CommEvents one real iteration would leave in the Monitor log."""
        k = len(self.ITER_PATTERN)
        return [
            CommEvent(op=op, timestamp=t_start + iter_time * (i / k), rank=rank)
            for i, op in enumerate(self.ITER_PATTERN)
        ]

    # ------------------------------------- ClusterInterface (FALCON R1)
    def profile_groups(self) -> dict[str, float]:
        """Per-communication-group transfer time (profiling phase)."""
        lay = self._layout()
        out: dict[str, float] = {}
        m = self.job.model
        if lay.tp_edges is not None:
            tp_vol = m.comm_tp_bytes(self.job.tp, self.job.pp, 1)
            bw = self.state.link_bw_many(*lay.tp_edges).reshape(
                self.job.pp, self.job.dp, self.job.tp
            ).min(axis=2)
            times = 2.0 * (self.job.tp - 1) / self.job.tp * tp_vol / bw
            out.update(zip(lay.tp_keys, times.reshape(-1).tolist(), strict=True))
        if lay.dp_edges is not None:
            dp_vol = m.comm_dp_bytes(self.job.tp, self.job.pp)
            times = self._dp_ring_times(dp_vol)
            out.update(zip(lay.dp_keys, times.reshape(-1).tolist(), strict=True))
        return out

    def group_ranks(self, group: str) -> list[int]:
        kind, coords = group.split(":")
        if kind == "tp":
            s, d = coords[1:].split("d")
            return self._cell_devices(int(s), int(d))
        s, k = coords[1:].split("t")
        return [self.device_at(int(s), d, int(k)) for d in range(self.job.dp)]

    def benchmark_compute(self, ranks: list[int]) -> dict[int, float]:
        """GEMM validation: time inversely proportional to device speed.

        CPU contention does *not* show up here (paper case study 1: the GPU
        matmul test found no degradation) — only compute_speed matters.
        """
        return {
            r: self.cluster.gemm_ref_time / self.state.devices[r].compute_speed
            for r in ranks
        }

    def measure_link(self, pair: tuple[int, int]) -> float:
        a, b = pair
        return self.cluster.p2p_payload / self.state.link_bw(a, b)

    def measure_links(self, pairs: np.ndarray) -> np.ndarray:
        """Batched :meth:`measure_link` over an (k, 2) pair array.

        Rides on :meth:`ClusterState.link_bw_many`, so one call validates
        every ring pass of every suspicious group — the detector's
        vectorized validation sweep."""
        pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
        return self.cluster.p2p_payload / self.state.link_bw_many(
            pairs[:, 0], pairs[:, 1]
        )

    def healthy_link_time(self, pair: tuple[int, int]) -> float:
        """Expected healthy time for this link class (fabric is known)."""
        a, b = pair
        return self.cluster.p2p_payload / self.cluster.base_link_bw(a, b)

    def healthy_link_times(self, pairs: np.ndarray) -> np.ndarray:
        """Batched :meth:`healthy_link_time` over an (k, 2) pair array."""
        pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
        return self.cluster.p2p_payload / self.cluster.base_link_bw_many(
            pairs[:, 0], pairs[:, 1]
        )

    def healthy_compute_time(self) -> float:
        """Reference GEMM time on a healthy device."""
        return self.cluster.gemm_ref_time

    # -------------------------------------- node-scoped validation surface
    def node_of_rank(self, rank: int) -> int:
        """Node hosting a device rank (NIC/host clustering in validation)."""
        return self.cluster.node_of(rank)

    def benchmark_host(self, nodes: list[int]) -> dict[int, float]:
        """Host-side benchmark per node: CPU contention slows the whole
        node's host path, which the GPU GEMM sweep cannot see."""
        per = self.cluster.gpus_per_node
        out: dict[int, float] = {}
        for n in nodes:
            speed = min(
                self.state.devices[d].host_speed
                for d in range(n * per, (n + 1) * per)
            )
            out[n] = self.cluster.host_ref_time / speed
        return out

    def healthy_host_time(self) -> float:
        """Reference host benchmark time on a healthy node."""
        return self.cluster.host_ref_time

    def measure_nic(self, node: int) -> float:
        """P2P time through one node's NIC port (inter-node path)."""
        return self.cluster.p2p_payload / (
            self.cluster.inter_node_bw * self.state.nic_mult.get(node, 1.0)
        )

    def healthy_nic_time(self) -> float:
        """Expected healthy inter-node P2P time (NIC at full rate)."""
        return self.cluster.p2p_payload / self.cluster.inter_node_bw
