"""Labeled iteration-time trace generation for detector benchmarks.

Reproduces the *shape* of the characterization traces (§3): healthy jitter,
occasional single-iteration spikes, and step-like fail-slow episodes whose
onset/relief indices are the ground-truth labels for Tables 4-5.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class LabeledEpisode:
    onset: int  # iteration index of onset
    relief: int  # iteration index of recovery
    severity: float  # relative slowdown, e.g. 0.3 => 1.3x iteration time
    #: iterations over which the slowdown ramps up linearly (0 = step onset;
    #: network congestion typically builds up gradually)
    ramp: int = 0


@dataclass
class LabeledTrace:
    times: np.ndarray
    episodes: list[LabeledEpisode] = field(default_factory=list)

    @property
    def has_failslow(self) -> bool:
        return bool(self.episodes)


def generate_trace(
    rng: np.random.Generator,
    n_iters: int = 600,
    base_time: float = 1.0,
    jitter: float = 0.01,
    spike_prob: float = 0.0005,
    episodes: list[LabeledEpisode] | None = None,
) -> LabeledTrace:
    """One sampling-job trace with the given fail-slow episodes baked in."""
    t = rng.normal(base_time, jitter * base_time, size=n_iters)
    # Occasional one-iteration spikes (dataloader hiccups, GC) — the jitter
    # the verification step must not mistake for fail-slow.
    spikes = rng.random(n_iters) < spike_prob
    t[spikes] *= rng.uniform(1.1, 1.3, size=int(spikes.sum()))
    for ep in episodes or []:
        lo, hi = max(0, ep.onset), min(n_iters, ep.relief)
        mult = np.full(hi - lo, 1.0 + ep.severity)
        if ep.ramp > 0:
            k = min(ep.ramp, hi - lo)
            mult[:k] = 1.0 + ep.severity * np.linspace(1.0 / k, 1.0, k)
        t[lo:hi] *= mult
    return LabeledTrace(times=np.maximum(t, 1e-6), episodes=list(episodes or []))


def episodes_from_injections(
    injections,
    tick_seconds: float,
    n_ticks: int,
) -> list[LabeledEpisode]:
    """Express an injection schedule as labeled episodes in tick space.

    Bridges the two ground-truth vocabularies: the scenario engine samples
    :class:`~repro.cluster.injector.Injection` schedules in wall-clock
    seconds, while the detector benchmarks and the scoring layer label
    traces in iteration/tick indices. Episodes entirely outside the horizon
    are dropped; the rest are clamped to it.
    """
    out: list[LabeledEpisode] = []
    for inj in injections:
        onset = int(inj.start / tick_seconds)
        relief = int(np.ceil(inj.end / tick_seconds))
        if onset >= n_ticks or relief <= 0:
            continue
        out.append(
            LabeledEpisode(
                onset=max(0, onset),
                relief=min(relief, n_ticks),
                severity=float(inj.severity),
                ramp=int(np.ceil(inj.ramp / tick_seconds)),
            )
        )
    return out


def sample_campaign(
    seed: int,
    n_jobs: int,
    failslow_rate: float,
    n_iters: int = 600,
    min_severity: float = 0.12,
    max_severity: float = 0.8,
) -> list[LabeledTrace]:
    """A campaign of sampling jobs, a fraction of which fail slow (§3.2/3.3)."""
    rng = np.random.default_rng(seed)
    traces: list[LabeledTrace] = []
    for _ in range(n_jobs):
        episodes: list[LabeledEpisode] = []
        if rng.random() < failslow_rate:
            n_ep = int(rng.integers(1, 3))
            starts = np.sort(rng.integers(40, n_iters - 80, size=n_ep))
            for s in starts:
                roll = rng.random()
                ramp = 0
                if roll < 0.2:
                    # Short transient episode (tens of seconds in Fig. 1's
                    # duration CDF): only a few iterations long — these are
                    # what dilution-prone window detectors miss.
                    dur = int(rng.integers(4, 9))
                    sev = float(rng.uniform(max(0.2, min_severity), max_severity))
                elif roll < 0.5:
                    # Gradual-onset episode: congestion builds up over tens of
                    # iterations, so no two nearby windows ever differ by the
                    # detection threshold — fixed-offset comparisons miss it.
                    dur = int(rng.integers(60, max(61, n_iters // 3)))
                    sev = float(rng.uniform(max(0.2, min_severity), max_severity))
                    ramp = int(rng.integers(30, 60))
                else:
                    dur = int(rng.integers(30, max(31, n_iters // 3)))
                    sev = float(rng.uniform(min_severity, max_severity))
                episodes.append(
                    LabeledEpisode(
                        onset=int(s),
                        relief=min(int(s) + dur, n_iters - 10),
                        severity=sev,
                        ramp=ramp,
                    )
                )
            # Drop overlapping episodes (keep the first of each overlap).
            pruned: list[LabeledEpisode] = []
            last_end = -10**9
            for ep in episodes:
                if ep.onset > last_end + 20:
                    pruned.append(ep)
                    last_end = ep.relief
            episodes = pruned
        traces.append(generate_trace(rng, n_iters=n_iters, episodes=episodes))
    return traces
