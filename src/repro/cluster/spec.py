"""Hardware and job specifications for the cluster performance model.

Default numbers model the paper's testbed-class hardware for the simulator
(H800-like compute, NVSwitch intra-node, 400 Gbps RoCE/IB inter-node) and
TPU v5e for the roofline analysis of the JAX runtime (the dry-run target).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

# --- TPU v5e constants (roofline target; per chip) -----------------------
TPU_PEAK_FLOPS_BF16 = 197e12  # FLOP/s
TPU_HBM_BW = 819e9  # bytes/s
TPU_ICI_BW = 50e9  # bytes/s per link

# --- GPU-cluster constants (simulator; per device) ------------------------
H800_TFLOPS = 989e12 / 2  # dense bf16 w/o sparsity
NVSWITCH_BW = 400e9  # bytes/s intra-node effective
PIX_BW = 64e9  # PCIe switch
RDMA_BW = 50e9  # 400 Gbps RoCE/IB per NIC in bytes/s


@dataclass(frozen=True)
class ModelSpec:
    """Transformer shape, following the paper's Appendix 9.2 notation."""

    layers: int
    hidden: int
    seq_len: int
    vocab: int
    micro_batch: int = 1  # b: sequences per micro-batch

    @property
    def params(self) -> float:
        """N ~= 12 L h^2 (Eq. 6)."""
        return 12.0 * self.layers * self.hidden**2 + self.vocab * self.hidden

    def flops_per_microbatch(self) -> float:
        """Fwd+bwd FLOPs for one micro-batch: ~6 N b s."""
        return 6.0 * self.params * self.micro_batch * self.seq_len

    # Communication volumes per iteration (Appendix 9.2), in bytes (bf16).
    def comm_tp_bytes(self, t: int, p: int, m: int) -> float:
        if t <= 1:
            return 0.0
        return 2.0 * 8 * self.micro_batch * m * self.seq_len * self.hidden * (
            self.layers * (t - 1) / (p * t)
        )

    def comm_dp_bytes(self, t: int, p: int) -> float:
        return 2.0 * self.params / (p * t)  # k = 1 gradient pass, bf16

    def comm_pp_bytes(self, m: int) -> float:
        return 2.0 * m * self.micro_batch * self.seq_len * self.hidden


@dataclass
class ClusterSpec:
    """A homogeneous GPU cluster: nodes x GPUs, two-tier network."""

    n_nodes: int
    gpus_per_node: int = 8
    gpu_flops: float = H800_TFLOPS
    intra_node_bw: float = NVSWITCH_BW
    inter_node_bw: float = RDMA_BW
    #: benchmark GEMM reference time on a healthy GPU (s)
    gemm_ref_time: float = 0.05
    #: P2P validation payload (bytes)
    p2p_payload: float = 256e6
    #: host-side (CPU/dataloader) benchmark reference time on a healthy node
    #: (s) — the validation probe that exposes CPU contention, which GPU
    #: GEMMs cannot see (paper case study 1)
    host_ref_time: float = 0.5

    @property
    def n_devices(self) -> int:
        return self.n_nodes * self.gpus_per_node

    def node_of(self, device: int) -> int:
        return device // self.gpus_per_node

    def base_link_bw(self, a: int, b: int) -> float:
        """Healthy bandwidth of the physical path between devices a and b."""
        if a == b:
            return float("inf")
        if self.node_of(a) == self.node_of(b):
            return self.intra_node_bw
        return self.inter_node_bw

    def base_link_bw_many(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`base_link_bw` (keep the two in lockstep: the
        detector's batched and scalar healthy-reference paths must agree)."""
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        bw = np.where(
            a // self.gpus_per_node == b // self.gpus_per_node,
            self.intra_node_bw,
            self.inter_node_bw,
        )
        return np.where(a == b, np.inf, bw)


#: retained mutation-log entries; readers whose cursor falls off the tail
#: get a conservative full-dirty set (they rebuild, exactly as before the
#: log existed), so the cap bounds memory without a correctness cliff
_LOG_CAP = 8192

#: unique ClusterState identity tokens — ``id()`` can be reused after GC,
#: which would let a reader mistake a fresh state for the one its cursor
#: (and cached reductions) were built against
_STATE_UIDS = itertools.count()


@dataclass(frozen=True)
class DirtySet:
    """Typed components mutated since a reader's cursor.

    ``full`` means the reader's cursor predates the retained log (or a
    legacy whole-state bump happened): everything must be treated dirty.
    The three component sets mirror the state's storage: device indices
    (compute *or* host speed changed), ``(min, max)`` link keys, NIC nodes.
    """

    full: bool = False
    devices: frozenset[int] = frozenset()
    links: frozenset[tuple[int, int]] = frozenset()
    nics: frozenset[int] = frozenset()

    def __bool__(self) -> bool:
        return self.full or bool(self.devices or self.links or self.nics)


_EMPTY_DIRTY = DirtySet()
_FULL_DIRTY = DirtySet(full=True)


class DeviceState:
    """Dynamic per-device health (multipliers; 1.0 = healthy).

    A view into the owning :class:`ClusterState`'s speed arrays: writes land
    in the vectorized storage and append to the state's mutation log, so the
    simulator's memoized iteration time invalidates on *any* mutation path —
    including direct ``state.devices[i].compute_speed = ...`` assignments —
    and incremental readers learn exactly which device moved.
    """

    __slots__ = ("_state", "_idx")

    def __init__(self, state: "ClusterState", idx: int) -> None:
        self._state = state
        self._idx = idx

    @property
    def compute_speed(self) -> float:  # GPU degradation / thermal throttling
        return float(self._state._compute[self._idx])

    @compute_speed.setter
    def compute_speed(self, v: float) -> None:
        if self._state._compute[self._idx] != v:
            self._state._compute[self._idx] = v
            self._state._note_device(self._idx)

    @property
    def host_speed(self) -> float:  # CPU contention (affects whole node)
        return float(self._state._host[self._idx])

    @host_speed.setter
    def host_speed(self, v: float) -> None:
        if self._state._host[self._idx] != v:
            self._state._host[self._idx] = v
            self._state._note_device(self._idx)

    def __repr__(self) -> str:
        return (f"DeviceState(compute_speed={self.compute_speed}, "
                f"host_speed={self.host_speed})")


class _VersionedDict(dict):
    """Dict that logs key-scoped mutations into its owner's mutation log."""

    __slots__ = ("_owner", "_kind")

    def __init__(self, owner: "ClusterState", kind: str, *args) -> None:
        super().__init__(*args)
        self._owner = owner
        self._kind = kind

    def __setitem__(self, key, value) -> None:
        if key in self and dict.__getitem__(self, key) == value:
            return
        super().__setitem__(key, value)
        self._owner._note(self._kind, key)

    def __delitem__(self, key) -> None:
        super().__delitem__(key)
        self._owner._note(self._kind, key)

    def pop(self, key, *default):
        had = key in self
        out = super().pop(key, *default)
        if had:
            self._owner._note(self._kind, key)
        return out

    def clear(self) -> None:
        if self:
            keys = list(self)
            super().clear()
            for key in keys:
                self._owner._note(self._kind, key)

    def update(self, *args, **kw) -> None:
        keys = list(dict(*args, **kw))
        super().update(*args, **kw)
        for key in keys:
            self._owner._note(self._kind, key)

    def setdefault(self, key, default=None):
        if key in self:
            return dict.__getitem__(self, key)
        super().__setitem__(key, default)
        self._owner._note(self._kind, key)
        return default

    def __ior__(self, other):
        self.update(other)
        return self

    def popitem(self):
        out = super().popitem()
        self._owner._note(self._kind, out[0])
        return out


@dataclass
class ClusterState:
    """Mutable health state of every device and link.

    Speeds are stored as dense arrays for the simulator's vectorized fast
    path. Every mutation (through device views, the versioned multiplier
    dicts, or ``reset``) appends a *typed* entry to a bounded mutation log;
    readers hold a cursor (:meth:`cursor`) and ask :meth:`dirty_since` for
    the :class:`DirtySet` of components that moved — the invalidation
    contract incremental iteration-time recomputation is built on (see
    docs/simulator.md). ``version`` — the log's write position — is kept as
    the derived compatibility property coarse-grained memo keys still use.
    """

    spec: ClusterSpec
    devices: list[DeviceState] = field(init=False)
    #: (min(a,b), max(a,b)) -> bandwidth multiplier
    link_mult: dict[tuple[int, int], float] = field(default_factory=dict)
    #: node -> NIC bandwidth multiplier (RoCE congestion hits the whole port,
    #: slowing every inter-node flow of that node, not one cable)
    nic_mult: dict[int, float] = field(default_factory=dict)

    def __post_init__(self) -> None:
        n = self.spec.n_devices
        self._uid = next(_STATE_UIDS)
        self._version = 0
        self._log: list[tuple[str, object]] = []
        self._log_base = 0  # version index of _log[0]
        self._compute = np.ones(n)
        self._host = np.ones(n)
        #: devices whose compute or host speed is currently != 1.0 — lets
        #: ``reset`` touch (and dirty) only what was actually degraded
        self._degraded: set[int] = set()
        #: memoized sorted-key lookup tables for ``link_bw_many`` (rebuilt
        #: lazily after any link/NIC mutation, so steady-state vectorized
        #: sweeps stop re-sorting the multiplier dicts every call)
        self._link_lookup: tuple[np.ndarray, np.ndarray] | None = None
        self._nic_lookup: np.ndarray | None = None
        self.devices = [DeviceState(self, i) for i in range(n)]
        self.link_mult = _VersionedDict(self, "link", self.link_mult)
        self.nic_mult = _VersionedDict(self, "nic", self.nic_mult)
        self._clean = not self.link_mult and not self.nic_mult

    @property
    def uid(self) -> int:
        """Process-unique identity token (never reused, unlike ``id()``)."""
        return self._uid

    @property
    def version(self) -> int:
        return self._version

    # ----------------------------------------------------- mutation log
    def _note(self, kind: str, ident) -> None:
        """Append one typed mutation entry and advance the version."""
        self._log.append((kind, ident))
        if len(self._log) > _LOG_CAP:
            drop = len(self._log) - _LOG_CAP // 2
            del self._log[:drop]
            self._log_base += drop
        self._version += 1
        self._clean = False
        if kind == "link":
            self._link_lookup = None
        elif kind == "nic" and self._nic_lookup is not None:
            # The NIC table is dense per node: patch the entry in place
            # (the dict is already updated when _note fires).
            self._nic_lookup[ident] = self.nic_mult.get(ident, 1.0)

    def _note_device(self, idx: int) -> None:
        if self._compute[idx] == 1.0 and self._host[idx] == 1.0:
            self._degraded.discard(idx)
        else:
            self._degraded.add(idx)
        self._note("dev", idx)

    def _bump(self) -> None:
        """Legacy whole-state invalidation (kept for external callers):
        advances the version with an untyped entry, which readers must
        treat as everything-dirty."""
        self._note("all", None)

    def cursor(self) -> int:
        """Current mutation-log position; pass to :meth:`dirty_since`."""
        return self._version

    def dirty_since(self, cursor: int) -> DirtySet:
        """Aggregate the typed mutations since ``cursor`` (see
        :class:`DirtySet`). A cursor older than the retained log window —
        or from before this state existed — yields ``full=True``."""
        if cursor >= self._version:
            return _EMPTY_DIRTY
        start = cursor - self._log_base
        if start < 0:
            return _FULL_DIRTY
        devices: set[int] = set()
        links: set[tuple[int, int]] = set()
        nics: set[int] = set()
        for kind, ident in self._log[start:]:
            if kind == "dev":
                devices.add(ident)
            elif kind == "link":
                links.add(ident)
            elif kind == "nic":
                nics.add(ident)
            else:  # legacy _bump
                return _FULL_DIRTY
        return DirtySet(
            devices=frozenset(devices),
            links=frozenset(links),
            nics=frozenset(nics),
        )

    def reset(self) -> None:
        """Restore full health, dirtying only what was actually degraded
        (per-component entries, not a whole-state invalidation — the
        injector's reset/reapply cycle stays event-scoped)."""
        if self._clean:
            return
        for i in sorted(self._degraded):
            self._compute[i] = 1.0
            self._host[i] = 1.0
            self._note("dev", i)
        self._degraded.clear()
        for key in list(self.link_mult):
            self._note("link", key)
        for node in list(self.nic_mult):
            self._note("nic", node)
        dict.clear(self.link_mult)
        dict.clear(self.nic_mult)
        # The notes above ran against the still-populated dicts; drop the
        # memoized lookups outright rather than patching stale entries.
        self._link_lookup = None
        self._nic_lookup = None
        self._clean = True

    def effective_speed(self, device: int) -> float:
        return float(self._compute[device] * self._host[device])

    def effective_speeds(self) -> np.ndarray:
        """Per-device effective speed vector (compute x host)."""
        return self._compute * self._host

    def link_bw(self, a: int, b: int) -> float:
        base = self.spec.base_link_bw(a, b)
        key = (min(a, b), max(a, b))
        bw = base * self.link_mult.get(key, 1.0)
        na, nb = self.spec.node_of(a), self.spec.node_of(b)
        if na != nb:
            bw *= min(self.nic_mult.get(na, 1.0), self.nic_mult.get(nb, 1.0))
        return bw

    def link_bw_many(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`link_bw` over parallel device-index arrays.

        Applies the exact same operation chain per element (base, then the
        link multiplier, then the NIC factor), so results match the scalar
        path bit for bit; degraded links/NICs are applied as sparse masks —
        O(len + #degraded) instead of a Python loop per edge.
        """
        spec = self.spec
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        na = a // spec.gpus_per_node
        nb = b // spec.gpus_per_node
        cross = na != nb
        bw = np.where(cross, spec.inter_node_bw, spec.intra_node_bw)
        bw = np.where(a == b, np.inf, bw)
        if self.link_mult:
            # One sorted-key lookup for all degraded links: O(len log m),
            # not a full-length mask per degraded link. The sorted tables
            # are memoized on the state until the next link mutation.
            n = spec.n_devices
            keys = np.minimum(a, b) * n + np.maximum(a, b)
            if self._link_lookup is None:
                items = sorted(
                    (klo * n + khi, mult)
                    for (klo, khi), mult in self.link_mult.items()
                )
                self._link_lookup = (
                    np.array([k for k, _ in items], dtype=np.int64),
                    np.array([m for _, m in items]),
                )
            dk, dm = self._link_lookup
            pos = np.minimum(np.searchsorted(dk, keys), dk.size - 1)
            hit = dk[pos] == keys
            if hit.any():
                bw = np.where(hit, bw * dm[pos], bw)
        if self.nic_mult:
            if self._nic_lookup is None:
                nm = np.ones(spec.n_nodes)
                for node, mult in self.nic_mult.items():
                    nm[node] = mult
                self._nic_lookup = nm
            nm = self._nic_lookup
            factor = np.minimum(nm[na], nm[nb])
            bw = np.where(cross, bw * factor, bw)
        return bw

    def degrade_link(self, a: int, b: int, mult: float) -> None:
        self.link_mult[(min(a, b), max(a, b))] = mult

    def restore_link(self, a: int, b: int) -> None:
        self.link_mult.pop((min(a, b), max(a, b)), None)

    def degrade_nic(self, node: int, mult: float) -> None:
        self.nic_mult[node] = mult

    def restore_nic(self, node: int) -> None:
        self.nic_mult.pop(node, None)
