"""Hardware and job specifications for the cluster performance model.

Default numbers model the paper's testbed-class hardware for the simulator
(H800-like compute, NVSwitch intra-node, 400 Gbps RoCE/IB inter-node) and
TPU v5e for the roofline analysis of the JAX runtime (the dry-run target).
"""
from __future__ import annotations

from dataclasses import dataclass, field

# --- TPU v5e constants (roofline target; per chip) -----------------------
TPU_PEAK_FLOPS_BF16 = 197e12  # FLOP/s
TPU_HBM_BW = 819e9  # bytes/s
TPU_ICI_BW = 50e9  # bytes/s per link

# --- GPU-cluster constants (simulator; per device) ------------------------
H800_TFLOPS = 989e12 / 2  # dense bf16 w/o sparsity
NVSWITCH_BW = 400e9  # bytes/s intra-node effective
PIX_BW = 64e9  # PCIe switch
RDMA_BW = 50e9  # 400 Gbps RoCE/IB per NIC in bytes/s


@dataclass(frozen=True)
class ModelSpec:
    """Transformer shape, following the paper's Appendix 9.2 notation."""

    layers: int
    hidden: int
    seq_len: int
    vocab: int
    micro_batch: int = 1  # b: sequences per micro-batch

    @property
    def params(self) -> float:
        """N ~= 12 L h^2 (Eq. 6)."""
        return 12.0 * self.layers * self.hidden**2 + self.vocab * self.hidden

    def flops_per_microbatch(self) -> float:
        """Fwd+bwd FLOPs for one micro-batch: ~6 N b s."""
        return 6.0 * self.params * self.micro_batch * self.seq_len

    # Communication volumes per iteration (Appendix 9.2), in bytes (bf16).
    def comm_tp_bytes(self, t: int, p: int, m: int) -> float:
        if t <= 1:
            return 0.0
        return 2.0 * 8 * self.micro_batch * m * self.seq_len * self.hidden * (
            self.layers * (t - 1) / (p * t)
        )

    def comm_dp_bytes(self, t: int, p: int) -> float:
        return 2.0 * self.params / (p * t)  # k = 1 gradient pass, bf16

    def comm_pp_bytes(self, m: int) -> float:
        return 2.0 * m * self.micro_batch * self.seq_len * self.hidden


@dataclass
class ClusterSpec:
    """A homogeneous GPU cluster: nodes x GPUs, two-tier network."""

    n_nodes: int
    gpus_per_node: int = 8
    gpu_flops: float = H800_TFLOPS
    intra_node_bw: float = NVSWITCH_BW
    inter_node_bw: float = RDMA_BW
    #: benchmark GEMM reference time on a healthy GPU (s)
    gemm_ref_time: float = 0.05
    #: P2P validation payload (bytes)
    p2p_payload: float = 256e6

    @property
    def n_devices(self) -> int:
        return self.n_nodes * self.gpus_per_node

    def node_of(self, device: int) -> int:
        return device // self.gpus_per_node

    def base_link_bw(self, a: int, b: int) -> float:
        """Healthy bandwidth of the physical path between devices a and b."""
        if a == b:
            return float("inf")
        if self.node_of(a) == self.node_of(b):
            return self.intra_node_bw
        return self.inter_node_bw


@dataclass
class DeviceState:
    """Dynamic per-device health (multipliers; 1.0 = healthy)."""

    compute_speed: float = 1.0  # GPU degradation / thermal throttling
    host_speed: float = 1.0  # CPU contention (affects whole node)


@dataclass
class ClusterState:
    """Mutable health state of every device and link."""

    spec: ClusterSpec
    devices: list[DeviceState] = field(init=False)
    #: (min(a,b), max(a,b)) -> bandwidth multiplier
    link_mult: dict[tuple[int, int], float] = field(default_factory=dict)
    #: node -> NIC bandwidth multiplier (RoCE congestion hits the whole port,
    #: slowing every inter-node flow of that node, not one cable)
    nic_mult: dict[int, float] = field(default_factory=dict)

    def __post_init__(self) -> None:
        self.devices = [DeviceState() for _ in range(self.spec.n_devices)]

    def reset(self) -> None:
        for d in self.devices:
            d.compute_speed = 1.0
            d.host_speed = 1.0
        self.link_mult.clear()
        self.nic_mult.clear()

    def effective_speed(self, device: int) -> float:
        d = self.devices[device]
        return d.compute_speed * d.host_speed

    def link_bw(self, a: int, b: int) -> float:
        base = self.spec.base_link_bw(a, b)
        key = (min(a, b), max(a, b))
        bw = base * self.link_mult.get(key, 1.0)
        na, nb = self.spec.node_of(a), self.spec.node_of(b)
        if na != nb:
            bw *= min(self.nic_mult.get(na, 1.0), self.nic_mult.get(nb, 1.0))
        return bw

    def degrade_link(self, a: int, b: int, mult: float) -> None:
        self.link_mult[(min(a, b), max(a, b))] = mult

    def restore_link(self, a: int, b: int) -> None:
        self.link_mult.pop((min(a, b), max(a, b)), None)

    def degrade_nic(self, node: int, mult: float) -> None:
        self.nic_mult[node] = mult

    def restore_nic(self, node: int) -> None:
        self.nic_mult.pop(node, None)
