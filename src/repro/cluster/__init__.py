"""Cluster performance-model substrate: specs, simulator, fail-slow injector."""
