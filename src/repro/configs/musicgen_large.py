"""MusicGen-large decoder over EnCodec tokens [arXiv:2306.05284].

Audio carve-out: the EnCodec codec is stubbed — inputs are 4 parallel
codebook token streams (B, S, K) which the model embeds and sums
(delay-pattern interleave handled by the data stub). One LM head per
codebook.
"""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    period=(SubLayer("attn", "mlp"),),
    pos_encoding="rope",
    sliding_window=4096,
    long_context="sliding",
    modality="audio_codes",
    num_codebooks=4,
    citation="arXiv:2306.05284",
)
