"""Mistral-Nemo-12B — 128k context GQA [hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,  # explicit: 5120 / 32 = 160, but Nemo uses 128
    period=(SubLayer("attn", "mlp"),),
    pos_encoding="rope",
    rope_theta=1e6,
    sliding_window=4096,
    long_context="sliding",
    citation="hf:mistralai/Mistral-Nemo-Base-2407",
)
