"""~100M dense model for the end-to-end FALCON training examples."""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="falcon-demo-100m",
    family="dense",
    num_layers=8,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    period=(SubLayer("attn", "mlp"),),
    pos_encoding="rope",
    rope_theta=1e4,
    citation="(demo model for examples/)",
)
