"""Yi-9B — llama-arch GQA [arXiv:2403.04652]."""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    period=(SubLayer("attn", "mlp"),),
    pos_encoding="rope",
    rope_theta=1e4,
    sliding_window=4096,
    long_context="sliding",
    citation="arXiv:2403.04652",
)
