"""Jamba-1.5-Large (398B) — Mamba+attention 1:7 interleave, 16-expert top-2
MoE on alternating layers [arXiv:2403.19887].

Period of 8 layers: 1 attention + 7 mamba; MoE MLP on every other layer.
TPU adaptation (see DESIGN.md): mamba layers use the SSD dual form
(MXU-friendly) rather than Mamba-1's sequential selective scan.
"""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    period=(
        SubLayer("mamba", "moe"),
        SubLayer("mamba", "mlp"),
        SubLayer("mamba", "moe"),
        SubLayer("mamba", "mlp"),
        SubLayer("attn", "moe"),
        SubLayer("mamba", "mlp"),
        SubLayer("mamba", "moe"),
        SubLayer("mamba", "mlp"),
    ),
    num_experts=16,
    top_k=2,
    moe_d_ff=24576,
    moe_shard="experts",
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    pos_encoding="none",  # Jamba uses no positional encoding
    long_context="native",
    citation="arXiv:2403.19887",
)
