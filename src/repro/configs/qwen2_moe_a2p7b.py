"""Qwen1.5-MoE-A2.7B — 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B].

60 experts do not divide the 16-way model axis; the expert dim is padded to
64 (router-masked dummies, EXPERIMENTS §Perf) so the expert-parallel
shard_map path applies — +6.7 % expert-weight memory for shard-local
dispatch. (The previous layout, ``moe_shard="ff"``, tensor-parallelized the
1408-wide FF *within* each expert and replicated the capacity buffers.)
"""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=151936,
    period=(SubLayer("attn", "moe"),),
    num_experts=60,
    top_k=4,
    moe_d_ff=1408,
    num_shared_experts=4,
    shared_d_ff=5632,
    moe_shard="experts",
    pad_experts_to=64,
    pos_encoding="rope",
    rope_theta=1e6,
    sliding_window=4096,
    long_context="sliding",
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
