"""Granite-3.0-8B — GQA [hf:ibm-granite/granite-3.0-2b-base family]."""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    period=(SubLayer("attn", "mlp"),),
    pos_encoding="rope",
    rope_theta=1e4,
    sliding_window=4096,
    long_context="sliding",
    citation="hf:ibm-granite/granite-3.0-2b-base",
)
