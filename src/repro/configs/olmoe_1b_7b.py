"""OLMoE-1B-7B — 64 experts, top-8 routing [arXiv:2409.02060]."""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,  # every MLP is MoE
    vocab_size=50304,
    period=(SubLayer("attn", "moe"),),
    num_experts=64,
    top_k=8,
    moe_d_ff=1024,
    moe_shard="experts",  # 64 % 16 == 0: expert-parallel over the model axis
    pos_encoding="rope",
    rope_theta=1e4,
    sliding_window=4096,
    long_context="sliding",
    citation="arXiv:2409.02060",
)
