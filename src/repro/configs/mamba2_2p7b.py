"""Mamba2-2.7B — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    period=(SubLayer("mamba", None),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    pos_encoding="none",
    long_context="native",
    citation="arXiv:2405.21060",
)
