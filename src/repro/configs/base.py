"""Architecture configuration schema + registry.

Each assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG``. Models are built from a *period*: the repeating pattern of
sub-layers (e.g. jamba = 1 attention + 7 mamba per 8 layers), which keeps
heterogeneous stacks scannable (`lax.scan` over periods).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace

import jax.numpy as jnp

#: input shapes assigned to this paper (global batch, seq_len, kind)
INPUT_SHAPES: dict[str, dict] = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}


@dataclass(frozen=True)
class SubLayer:
    """One sub-layer of the repeating period."""

    mixer: str  # "attn" | "mamba"
    mlp: str | None  # "mlp" | "moe" | None (mamba2 blocks carry no MLP)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    period: tuple[SubLayer, ...] = (SubLayer("attn", "mlp"),)

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden width (d_ff is the dense-MLP width)
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    #: "experts" = expert-parallel (E % tp == 0), "ff" = TP within experts
    moe_shard: str = "experts"
    #: pad the routed-expert count up to this (0 = no padding). Dummy
    #: experts are masked in the router and never receive tokens; padding
    #: 60 -> 64 lets qwen2-moe use the expert-parallel path (EXPERIMENTS
    #: §Perf) at +6.7 % expert-weight memory.
    pad_experts_to: int = 0

    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0  # N
    ssm_head_dim: int = 64  # P
    ssm_expand: int = 2
    ssm_groups: int = 1  # G (B/C groups)
    ssm_conv_width: int = 4
    ssm_chunk: int = 128  # SSD chunk length

    # --- positions / attention variants ---
    pos_encoding: str = "rope"  # rope | mrope | none
    rope_theta: float = 1e6
    sliding_window: int = 0  # 0 = full attention; >0 = serve-time window
    #: long_500k policy: "native" (SSM/hybrid), "sliding" (dense w/ window)
    long_context: str = "sliding"

    # --- modality stub (vlm / audio carve-out) ---
    modality: str = "text"  # text | vision_embeds | audio_codes
    num_codebooks: int = 0  # musicgen EnCodec codebooks

    # --- numerics ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def padded_experts(self) -> int:
        return max(self.num_experts, self.pad_experts_to)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the embedding/LM head
        always shard over the model axis (EXPERIMENTS §Perf: an unsharded
        49155-wide head replicates full-vocab logits on every TP shard).
        Padded logit columns are masked to -inf in apply_head."""
        return -(-self.vocab_size // 128) * 128

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def n_periods(self) -> int:
        assert self.num_layers % len(self.period) == 0, (
            f"{self.name}: {self.num_layers} layers not divisible by period "
            f"{len(self.period)}"
        )
        return self.num_layers // len(self.period)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def smoke(self) -> "ArchConfig":
        """Reduced variant of the same family for CPU smoke tests:
        2 periods worth of layers, d_model <= 512, <= 4 experts."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4) if self.num_heads else 0
        num_kv = max(1, min(self.num_kv_heads, num_heads)) if num_heads else 0
        experts = min(self.num_experts, 4) if self.num_experts else 0
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=2 * len(self.period),
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=d_model // num_heads if num_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=experts,
            pad_experts_to=0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            shared_d_ff=min(self.shared_d_ff, 128) if self.shared_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, 32),
            ssm_chunk=16,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            num_codebooks=self.num_codebooks,
        )

    def flops_per_token(self) -> float:
        """Active-parameter forward FLOPs per token ~ 2 * N_active."""
        return 2.0 * self.active_params()

    # -- parameter accounting (for roofline MODEL_FLOPS = 6 N D) ----------
    def _per_layer_params(self, sub: SubLayer, active: bool) -> float:
        d, hd = self.d_model, self.resolved_head_dim
        total = 0.0
        if sub.mixer == "attn":
            total += d * (self.num_heads * hd)  # Q
            total += 2 * d * (self.num_kv_heads * hd)  # K, V
            total += (self.num_heads * hd) * d  # O
        else:
            inner, h, g, n = self.ssm_inner, self.ssm_heads, self.ssm_groups, self.ssm_state
            total += d * 2 * inner  # z, x projections
            total += d * 2 * g * n + d * h  # B, C, dt
            total += inner * d  # out proj
            total += self.ssm_conv_width * inner + 2 * h + inner  # conv, A/D, norm
        if sub.mlp == "mlp":
            total += 3 * d * self.d_ff
        elif sub.mlp == "moe":
            e = self.top_k if active else self.num_experts
            total += 3 * d * self.moe_d_ff * e
            total += d * self.num_experts  # router
            if self.num_shared_experts:
                total += 3 * d * self.shared_d_ff * self.num_shared_experts
        total += 2 * d  # norms
        return total

    def _params(self, active: bool) -> float:
        per_period = sum(self._per_layer_params(s, active) for s in self.period)
        total = per_period * self.n_periods
        total += 2 * self.vocab_size * self.d_model * max(1, self.num_codebooks or 1)
        total += self.d_model  # final norm
        return total

    def total_params(self) -> float:
        return self._params(active=False)

    def active_params(self) -> float:
        return self._params(active=True)


_REGISTRY: dict[str, str] = {
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "musicgen-large": "repro.configs.musicgen_large",
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "granite-20b": "repro.configs.granite_20b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "yi-9b": "repro.configs.yi_9b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1p5_large",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "falcon-demo-100m": "repro.configs.falcon_demo_100m",
}


def list_archs() -> list[str]:
    return list(_REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[name]).CONFIG
