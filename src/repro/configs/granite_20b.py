"""Granite-20B code model — llama-arch with MQA (kv=1) [arXiv:2405.04324]."""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    period=(SubLayer("attn", "mlp"),),
    pos_encoding="rope",
    rope_theta=1e4,
    sliding_window=4096,
    long_context="sliding",
    citation="arXiv:2405.04324",
)
