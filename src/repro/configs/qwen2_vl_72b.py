"""Qwen2-VL-72B language backbone [arXiv:2409.12191].

VLM carve-out: the ViT vision encoder + projector are stubbed —
``input_specs`` feeds precomputed patch/text embeddings (B, S, D) plus
M-RoPE (temporal, height, width) position ids.
"""
from repro.configs.base import ArchConfig, SubLayer

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    period=(SubLayer("attn", "mlp"),),
    pos_encoding="mrope",
    rope_theta=1e6,
    sliding_window=4096,
    long_context="sliding",
    modality="vision_embeds",
    citation="arXiv:2409.12191",
)
