"""End-to-end driver: train the ~100M falcon-demo model for a few hundred
steps with FALCON protecting the run (deliverable b).

The model trains for real (8 layers, d=768, 32k vocab ~= 100M params; loss
decreases on the structured synthetic stream). The attached cluster
performance model replays a mixed fail-slow trace — computation and
communication episodes like the paper's Fig. 20 — and FALCON detects and
mitigates each one. The run prints a per-phase summary plus the strategy
timeline, and checkpoints at the end.

Run:  PYTHONPATH=src python examples/train_100m_falcon.py [--steps 200]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import FalconTrainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--no-falcon", action="store_true")
    args = ap.parse_args()

    cfg = get_config("falcon-demo-100m")
    n_params = cfg.total_params()
    print(f"model: {cfg.name} ({n_params/1e6:.0f}M params)")

    data = DataConfig(
        seq_len=args.seq_len, global_batch=8, slots=2, dp_groups=4
    )
    # Performance model: 2 nodes x 8 GPUs, (2TP, 4DP, 2PP).
    sim = TrainingSimulator(
        cluster=ClusterSpec(n_nodes=2, gpus_per_node=8),
        job=JobSpec(
            model=ModelSpec(layers=24, hidden=2048, seq_len=1024, vocab=32000),
            tp=2, dp=4, pp=2, micro_batches=16,
        ),
    )
    t0 = sim.healthy_iteration_time()
    injector = FailSlowInjector([
        # GPU 5 thermal-throttles early in the run.
        Injection(start=20 * t0, duration=60 * t0,
                  kind=InjectionKind.GPU_SLOW, target=(5,), severity=0.45),
        # Node 1's NIC congests mid-run (communication fail-slow).
        Injection(start=100 * t0, duration=70 * t0,
                  kind=InjectionKind.NIC_CONGESTION, target=(1,), severity=0.7),
    ])

    trainer = FalconTrainer(
        cfg=cfg,
        data=data,
        opt_cfg=AdamWConfig(lr=3e-4, warmup_steps=20),
        perf_model=sim,
        injector=injector,
        falcon_enabled=not args.no_falcon,
    )
    history = trainer.run(args.steps)

    losses = np.array([h.loss for h in history])
    times = np.array([h.iter_time for h in history])
    print(f"\nloss: first10={losses[:10].mean():.3f} "
          f"last10={losses[-10:].mean():.3f}")
    print(f"iteration time: healthy={t0:.2f}s "
          f"mean={times.mean():.2f}s p95={np.percentile(times, 95):.2f}s")
    print(f"total wall (modeled): {history[-1].wall_time/60:.1f} min")
    print("\nstrategy timeline:")
    for h in history:
        if h.strategy:
            print(f"  step {h.step:>4}: {h.strategy}")
    for ev in trainer.detector.history if trainer.detector else []:
        print(f"detected: {ev.root_cause.value} {ev.components} "
              f"({ev.t_healthy:.2f}s -> {ev.t_slow:.2f}s)")

    trainer.ckpt.save_disk(trainer.params, step=args.steps)
    print(f"\ncheckpoint saved to {trainer.ckpt.path(args.steps)}")
    assert losses[-10:].mean() < losses[:10].mean(), "loss should decrease"
    print("train_100m_falcon OK")


if __name__ == "__main__":
    main()
