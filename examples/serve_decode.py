"""Serving example: batched prefill + autoregressive decode with KV caches.

Runs two reduced architectures through the real serve path (deliverable b):

  * granite-3-8b (smoke)  — GQA attention with a KV cache,
  * mamba2-2.7b (smoke)   — attention-free; the "cache" is the SSM state,
    so per-token cost is O(1) in context length (why SSM/hybrid archs run
    the long_500k shape natively).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import model as model_lib, transformer
from repro.serve.serve_step import make_decode_step, make_prefill_step

BATCH, PROMPT, NEW_TOKENS = 4, 32, 8


def serve(arch: str, seed: int = 0) -> None:
    cfg = get_config(arch).smoke()
    params = model_lib.init_params(cfg, seed)
    rng = np.random.default_rng(seed)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(BATCH, PROMPT)), jnp.int32
    )
    if cfg.modality == "audio_codes":
        prompt = prompt[..., None].repeat(cfg.num_codebooks, -1)

    # 1) prefill the caches over the prompt.
    prefill = jax.jit(make_prefill_step(cfg, PROMPT))
    logits, caches = prefill(params, {"tokens": prompt})

    # Prefill returns period-stacked caches; decode consumes the same layout
    # but padded to the serving context length.
    total = PROMPT + NEW_TOKENS
    caches = transformer.grow_caches(caches, cfg, total)

    # 2) decode NEW_TOKENS greedily, one token per step.
    decode = jax.jit(make_decode_step(cfg, total))
    tok = jnp.argmax(logits[:, -1], axis=-1).reshape(BATCH, 1).astype(jnp.int32)
    if cfg.modality == "audio_codes" and tok.ndim == 2:
        tok = tok[..., None].repeat(cfg.num_codebooks, -1)
    out = []
    pos = jnp.asarray(PROMPT, jnp.int32)
    for _ in range(NEW_TOKENS):
        logits, caches = decode(params, tok, caches, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        if cfg.modality == "audio_codes":
            tok = nxt.reshape(BATCH, 1, cfg.num_codebooks).astype(jnp.int32)
            out.append(np.asarray(nxt)[..., 0])
        else:
            tok = nxt.reshape(BATCH, 1).astype(jnp.int32)
            out.append(np.asarray(nxt))
        pos = pos + 1
    gen = np.stack(out, axis=1)
    assert gen.shape[:2] == (BATCH, NEW_TOKENS)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"{arch:>22}: generated {gen.shape} tokens, "
          f"sample row: {gen[0].tolist()}")


def main() -> None:
    for arch in ("granite-3-8b", "mamba2-2.7b"):
        serve(arch)
    print("serve_decode OK")


if __name__ == "__main__":
    main()
