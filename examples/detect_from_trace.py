"""Framework-agnostic detection from a raw communication-call log.

This is FALCON-DETECT's tracking phase exactly as the paper describes it
(§4.2): the input is nothing but a sequence of (op_type, timestamp) events —
what the LD_PRELOAD shim logs — with no knowledge of the framework, model,
or parallelism strategy.

  1. ACF recovers the recurring period of the call pattern.
  2. Per-iteration times are derived from same-call timestamp deltas.
  3. BOCD + 10 % verification flags fail-slow onset and relief.

Run:  PYTHONPATH=src python examples/detect_from_trace.py
"""
from __future__ import annotations

import numpy as np

from repro.core.acf import iteration_times_from_events
from repro.core.detector import detect_slow_iterations
from repro.core.events import CommEvent, CommOp

# One training iteration issues this collective pattern (unknown to FALCON).
PATTERN = [CommOp.ALL_REDUCE, CommOp.SEND_RECV, CommOp.REDUCE_SCATTER,
           CommOp.ALL_GATHER, CommOp.SEND_RECV]
BASE_ITER = 1.8  # seconds
N_ITERS = 400


def synthesize_log(rng: np.random.Generator) -> list[CommEvent]:
    """A Monitor log: healthy -> congested (1.45x) at iter 150 -> recovered
    at iter 280."""
    phases = np.sort(rng.uniform(0.05, 0.9, size=len(PATTERN)))
    events, t = [], 0.0
    for i in range(N_ITERS):
        it = BASE_ITER * float(rng.normal(1.0, 0.01))
        if 150 <= i < 280:
            it *= 1.45
        offs = np.sort(phases * it + rng.normal(0, 2e-3, size=len(PATTERN)))
        events += [CommEvent(op=op, timestamp=t + o)
                   for op, o in zip(PATTERN, offs, strict=True)]
        t += it
    return events


def main() -> None:
    rng = np.random.default_rng(42)
    events = synthesize_log(rng)
    print(f"monitor log: {len(events)} communication calls, op types "
          f"{sorted({e.op.value for e in events})}")

    iter_times, period = iteration_times_from_events(events)
    print(f"ACF period: {period} calls/iteration "
          f"(ground truth {len(PATTERN)})")
    print(f"estimated healthy iteration: {np.median(iter_times[:100]):.3f}s "
          f"(ground truth {BASE_ITER:.3f}s)")

    cps = detect_slow_iterations(np.asarray(iter_times), hazard=1 / 100.0)
    print("\nconfirmed change-points:")
    for cp in cps:
        kind = "onset " if cp.relative_change > 0 else "relief"
        print(f"  iter {cp.index:>4}: {kind} {cp.mean_before:.2f}s -> "
              f"{cp.mean_after:.2f}s ({cp.relative_change:+.1%})")

    assert period == len(PATTERN)
    assert any(cp.relative_change > 0.3 for cp in cps), "onset missed"
    assert any(cp.relative_change < -0.2 for cp in cps), "relief missed"
    print("\ndetect_from_trace OK")


if __name__ == "__main__":
    main()
