"""Quickstart: FALCON in ~60 lines.

1. Train a tiny model for a handful of real JAX steps.
2. Attach the cluster performance model and inject a GPU fail-slow.
3. Watch FALCON-DETECT pinpoint it and FALCON-MITIGATE escalate S1 -> S2.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.cluster.injector import FailSlowInjector, Injection, InjectionKind
from repro.cluster.simulator import JobSpec, TrainingSimulator
from repro.cluster.spec import ClusterSpec, ModelSpec
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig
from repro.train.trainer import FalconTrainer


def main() -> None:
    # A reduced falcon-demo model (real parameters, real optimizer updates).
    cfg = get_config("falcon-demo-100m").smoke()
    data = DataConfig(seq_len=64, global_batch=16, slots=4, dp_groups=4)

    # The performance model: one 8-GPU node running (1TP, 4DP, 2PP).
    sim = TrainingSimulator(
        cluster=ClusterSpec(n_nodes=1, gpus_per_node=8),
        job=JobSpec(
            model=ModelSpec(layers=12, hidden=1024, seq_len=512, vocab=32000),
            tp=1, dp=4, pp=2, micro_batches=16,
        ),
    )
    # GPU 2 loses 50 % of its speed from iteration ~20 to ~60.
    t0 = sim.healthy_iteration_time()
    injector = FailSlowInjector([
        Injection(start=20 * t0, duration=40 * t0,
                  kind=InjectionKind.GPU_SLOW, target=(2,), severity=0.5)
    ])

    # Strategy overheads expressed in simulated-iteration units so the
    # ski-rental escalation is visible within this short run.
    from repro.core.events import Strategy

    overheads = {
        Strategy.IGNORE: 0.0,
        Strategy.ADJUST_MICROBATCH: 5 * t0,
        Strategy.ADJUST_TOPOLOGY: 60 * t0,
        Strategy.CKPT_AND_RESTART: 1000 * t0,
    }
    trainer = FalconTrainer(
        cfg=cfg, data=data, perf_model=sim, injector=injector,
        falcon_enabled=True, overheads=overheads,
    )
    history = trainer.run(80)

    print(f"{'step':>4} {'loss':>8} {'iter_s':>8}  strategy")
    for rec in history:
        if rec.step % 10 == 0 or rec.strategy:
            print(f"{rec.step:>4} {rec.loss:>8.3f} {rec.iter_time:>8.3f}  "
                  f"{rec.strategy or ''}")
    events = trainer.detector.history + (
        [trainer.detector.active_event] if trainer.detector.active_event else []
    )
    for ev in events:
        print(
            f"\nFALCON-DETECT: {ev.root_cause.value} on {ev.components}, "
            f"iteration {ev.t_healthy:.2f}s -> {ev.t_slow:.2f}s "
            f"(severity {ev.severity:.0%})"
        )
    assert history[-1].loss < history[0].loss, "loss should decrease"
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
